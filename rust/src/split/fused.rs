//! Fused, cache-blocked node-split pipeline: gather → route → accumulate
//! in one pass.
//!
//! The classic trainer materializes every candidate projection into a full
//! `n`-element buffer (`apply_projection`) and then re-streams that buffer
//! to route samples into histogram bins — one avoidable write + read of
//! `n × 4` bytes per projection per node. Figure 5 of the paper shows this
//! "sparse access" cost growing with depth until it rivals histogram fill;
//! GPU tree-boosting systems remove the same traffic by fusing binning
//! into the feature pass. This module is that fusion for the CPU path:
//!
//! * the active set is walked in cache-sized blocks ([`FUSED_BLOCK`] rows);
//! * per block, each projection's sparse column terms are gathered into one
//!   L1-resident buffer, routed through the existing two-level compare
//!   ([`super::vectorized`]) and accumulated into that projection's count
//!   table — the full projection vector never exists;
//! * iteration is **block-major** (all projections over block `b` before
//!   advancing), so the active-set indices, the labels and the source
//!   columns stay L1/L2-resident deep in the tree where the classic
//!   projection-major loop re-faults them per projection;
//! * only the *winning* projection is re-applied in full, once, for the
//!   partition step.
//!
//! Equivalence contract (enforced by `rust/tests/fused_equivalence.rs`):
//! the fused pipeline consumes the RNG in exactly the same sequence as the
//! classic path (boundary *positions* are drawn with the same
//! `rng.index(n)` calls), computes boundary values and routed bins with
//! bit-identical f32 arithmetic, and applies the same tie-breaking — so a
//! forest trained with `fused = on` is node-for-node identical to one
//! trained with `fused = off`.

use super::criterion::SplitCriterion;
use super::histogram::{best_edge_over_tables, Routing};
use super::scan::{self, SCAN_MAX_BINS};
use super::vectorized::{self, TwoLevelLayout};
use super::{Split, SplitScratch};
use crate::data::Dataset;
use crate::projection::apply::{active_span, apply_projection_into_span, project_row};
use crate::projection::Projection;
use crate::rng::Pcg64;

/// Rows per gather block: 1024 × 4 B of projected values plus 1024 × 2 B of
/// labels fit comfortably in L1 next to the boundary/coarse vectors, while
/// keeping the per-projection loop overhead amortized over ≥ 1k samples.
/// Tune against `benches/fused_pipeline.rs` (log results in EXPERIMENTS.md
/// §Perf before changing).
pub const FUSED_BLOCK: usize = 1024;

/// Find the best split across *all* candidate projections of a node in one
/// blocked pass. Returns the winning `(projection index, split)`, or `None`
/// when no projection admits a positive-gain split.
///
/// `labels` must be the node's gathered labels (`labels[i]` is the label of
/// sample `active[i]`). On return, `scratch.fused_counts` /
/// `scratch.fused_boundaries` / `scratch.fused_ok` hold the per-projection
/// histogram state (used by the equivalence tests and kept for debugging).
#[allow(clippy::too_many_arguments)]
pub fn best_split_fused(
    data: &Dataset,
    projections: &[Projection],
    active: &[u32],
    labels: &[u16],
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    routing: Routing,
    rng: &mut Pcg64,
    scratch: &mut SplitScratch,
) -> Option<(usize, Split)> {
    let n = active.len();
    debug_assert_eq!(labels.len(), n);
    debug_assert!(n_bins >= 2);
    if n < 2 {
        return None;
    }
    let n_classes = parent_counts.len();

    // ---- Phase 1: per-projection bin boundaries, without materializing ----
    build_candidate_boundaries(data, projections, active, n_bins, rng, scratch);

    let SplitScratch {
        block,
        fused_boundaries,
        fused_coarse,
        fused_ok,
        fused_counts,
        ..
    } = scratch;

    // ---- Phase 2: block-major gather + route + accumulate ----
    fill_tables_blocked(
        data,
        projections,
        &*fused_ok,
        active,
        labels,
        &*fused_boundaries,
        &*fused_coarse,
        n_bins,
        n_classes,
        routing,
        block,
        fused_counts,
    );

    // ---- Phase 3: edge scan per projection, same tie-breaking as the ----
    // classic projection loop (first strictly-greater gain wins). Shared
    // with the sibling-subtraction path.
    best_edge_over_tables(
        parent_counts,
        criterion,
        n_bins,
        min_leaf,
        &*fused_ok,
        &*fused_counts,
        &*fused_boundaries,
    )
}

/// Phase 1 of the fused engine, exposed on its own for the sharded
/// fill-local/merge-global pipeline: build every candidate projection's bin
/// boundaries (into `scratch.fused_boundaries` / `fused_coarse` /
/// `fused_ok`) without materializing any projection vector.
///
/// Boundary *positions* are drawn with the same `rng.index(n)` sequence as
/// `histogram::build_boundaries` on a materialized vector, and the sampled
/// values are computed with the same per-element arithmetic (`project_row`
/// ≡ `apply_projection`), so the boundaries — and the RNG state left behind
/// — are bit-identical to the classic path's. Callers that fill count
/// tables elsewhere (per shard, say) therefore keep the node's RNG stream
/// aligned with BOTH fresh-search engines, which is what lets a sharded
/// fill + merge reproduce single-store training byte-for-byte.
pub fn build_candidate_boundaries(
    data: &Dataset,
    projections: &[Projection],
    active: &[u32],
    n_bins: usize,
    rng: &mut Pcg64,
    scratch: &mut SplitScratch,
) {
    let n = active.len();
    let p = projections.len();
    let n_real = n_bins - 1;
    let layout = TwoLevelLayout::for_bins(n_bins);
    let groups = layout.map_or(0, |l| l.groups);
    let SplitScratch {
        block,
        fused_boundaries,
        fused_coarse,
        fused_ok,
        ..
    } = scratch;
    fused_boundaries.clear();
    fused_boundaries.resize(p * n_bins, f32::INFINITY);
    fused_coarse.clear();
    fused_coarse.resize(p * groups, f32::INFINITY);
    fused_ok.clear();
    fused_ok.resize(p, false);
    for (pi, proj) in projections.iter().enumerate() {
        if proj.is_empty() {
            continue; // classic path skips before touching the RNG
        }
        let b = &mut fused_boundaries[pi * n_bins..(pi + 1) * n_bins];
        // Eligible binned axis: the boundary table is a pure function of
        // the stored bin layout — no sampling, ZERO RNG draws. The classic
        // loop gates on the same pure predicate and takes the same branch,
        // so the streams stay aligned around the fast path.
        if let Some((_, negate, bl)) = super::boundaries::binned_axis_plan(data, proj, n_bins) {
            super::boundaries::layout_boundaries_into(b, bl, negate);
            if let Some(layout) = layout {
                let coarse = &mut fused_coarse[pi * groups..(pi + 1) * groups];
                super::boundaries::coarse_into(b, layout, coarse);
            }
            fused_ok[pi] = true;
            continue;
        }
        // The shared builder (`super::boundaries`, also behind the
        // materializing path's `build_boundaries`) samples boundary values
        // by projecting single rows on demand; the degenerate fallback's
        // min/max is one blocked pass — still no full materialization.
        let ok = super::boundaries::sample_into(
            &mut b[..n_real],
            n,
            rng,
            |i| project_row(data, proj, active[i]),
            || projected_min_max(data, proj, active, &mut *block),
        );
        if !ok {
            continue; // constant projection: no split possible
        }
        b[n_real] = f32::INFINITY;
        if let Some(layout) = layout {
            let coarse = &mut fused_coarse[pi * groups..(pi + 1) * groups];
            super::boundaries::coarse_into(b, layout, coarse);
        }
        fused_ok[pi] = true;
    }
}

/// Fill a `p × n_bins × n_classes` stack of count tables over `active`
/// for a FIXED, pre-built boundary set — the direct-fill half of the
/// sibling-subtraction path, and phase 2 of [`best_split_fused`]. No RNG
/// is consumed: boundaries (one `n_bins` segment per projection, each
/// +∞-padded) come from the caller, sampled or inherited. `coarse` must
/// hold one `groups`-slot segment per projection when `n_bins` has a
/// two-level layout (ignored otherwise). Projections with `!ok[pi]` keep
/// all-zero tables.
///
/// Labels are range-checked here in every build (promoted from the fill
/// fast paths' `debug_assert`s): an out-of-range label would silently
/// corrupt a neighboring bin's counts, and the subtraction trick makes a
/// corrupt table contagious to the sibling.
#[allow(clippy::too_many_arguments)]
pub fn fill_tables_blocked(
    data: &Dataset,
    projections: &[Projection],
    ok: &[bool],
    active: &[u32],
    labels: &[u16],
    boundaries: &[f32],
    coarse: &[f32],
    n_bins: usize,
    n_classes: usize,
    routing: Routing,
    block: &mut Vec<f32>,
    counts: &mut Vec<u32>,
) {
    let p = projections.len();
    debug_assert_eq!(active.len(), labels.len());
    debug_assert_eq!(ok.len(), p);
    debug_assert_eq!(boundaries.len(), p * n_bins);
    super::check_labels(labels, n_classes);
    let n_real = n_bins - 1;
    let layout = TwoLevelLayout::for_bins(n_bins);
    let groups = layout.map_or(0, |l| l.groups);
    debug_assert!(layout.is_none() || coarse.len() == p * groups);
    let stride = n_bins * n_classes;
    counts.clear();
    counts.resize(p * stride, 0);
    block.resize(FUSED_BLOCK, 0.0);
    for (ablock, lblock) in active.chunks(FUSED_BLOCK).zip(labels.chunks(FUSED_BLOCK)) {
        let vals = &mut block[..ablock.len()];
        // One id span per block (not per projection): every projection's
        // member-column chunks for this block cover the same sample range.
        let span = active_span(ablock);
        for (pi, proj) in projections.iter().enumerate() {
            if !ok[pi] {
                continue;
            }
            let bounds = &boundaries[pi * n_bins..(pi + 1) * n_bins];
            let cnt = &mut counts[pi * stride..(pi + 1) * stride];
            // Eligible binned axis: accumulate straight off the stored u8
            // bin ids — no float gather, no routing compare. `bounds` can
            // be ignored because an eligible projection's boundary table is
            // ALWAYS the layout-derived one (a pure function of the store
            // and the projection), whether it was built by phase 1 above,
            // the classic loop, or inherited through sibling subtraction;
            // the routed bin of a dequantized value over those boundaries
            // is exactly the stored bin id (mirrored when negated).
            if let Some((f, negate, bl)) = super::boundaries::binned_axis_plan(data, proj, n_bins) {
                debug_assert!(plan_boundaries_match(bounds, bl, negate));
                super::histogram::accumulate_bin_ids(
                    data,
                    f,
                    negate,
                    bl.n_bins(),
                    ablock,
                    lblock,
                    n_classes,
                    cnt,
                );
                continue;
            }
            apply_projection_into_span(data, proj, ablock, span.clone(), vals);
            match (routing, layout) {
                (Routing::TwoLevel, Some(layout)) => {
                    let c = &coarse[pi * groups..(pi + 1) * groups];
                    vectorized::fill_two_level(vals, lblock, bounds, c, layout, n_classes, cnt);
                }
                _ if n_bins <= SCAN_MAX_BINS => {
                    scan::fill_scan(vals, lblock, bounds, n_bins, n_classes, cnt);
                }
                _ => {
                    // `bounds` ends in n_bins − n_real = 1 +∞ pad; when
                    // n_bins is a power of two that already satisfies the
                    // vector kernel's pow2 padding contract, otherwise the
                    // helper takes the bit-identical scalar route.
                    super::histogram::fill_lower_bound(
                        vals, lblock, bounds, n_real, n_classes, cnt,
                    );
                }
            }
        }
    }
}

/// Debug check behind the direct bin-id accumulate: `bounds` must equal the
/// layout-derived boundary table for this plan bit-for-bit. Eligible
/// projections always carry plan boundaries — sampled and inherited fills
/// alike — which is what licenses ignoring `bounds` in the fast path.
/// (Compiled in release too — `debug_assert!` type-checks its expression —
/// but branch-eliminated.)
fn plan_boundaries_match(bounds: &[f32], layout: &crate::data::BinLayout, negate: bool) -> bool {
    let mut expect = vec![0.0f32; bounds.len()];
    super::boundaries::layout_boundaries_into(&mut expect, layout, negate);
    bounds
        .iter()
        .zip(&expect)
        .all(|(a, b)| a.to_bits() == b.to_bits())
}

/// Blocked min/max of a projection over the active set (degenerate-boundary
/// fallback only). Elementwise `min`/`max` in active-set order — the same
/// fold, in the same order, as the classic path over a materialized vector,
/// so the results (including NaN handling) are identical.
fn projected_min_max(
    data: &Dataset,
    proj: &Projection,
    active: &[u32],
    block: &mut Vec<f32>,
) -> (f32, f32) {
    block.resize(FUSED_BLOCK, 0.0);
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    for ablock in active.chunks(FUSED_BLOCK) {
        let vals = &mut block[..ablock.len()];
        apply_projection_into_span(data, proj, ablock, active_span(ablock), vals);
        for &v in vals.iter() {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::projection::apply::{apply_projection, gather_labels};
    use crate::split::{best_split, SplitMethod};

    /// Random dataset + sparse projections for equivalence checks.
    fn setup(
        rng: &mut Pcg64,
        n: usize,
        d: usize,
        n_classes: usize,
    ) -> (Dataset, Vec<Projection>) {
        let columns: Vec<Vec<f32>> = (0..d)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let labels: Vec<u16> = (0..n).map(|i| (i % n_classes) as u16).collect();
        let data = Dataset::from_columns(columns, labels);
        let projections: Vec<Projection> = (0..6)
            .map(|_| {
                let k = 1 + rng.index(3);
                let terms = (0..k)
                    .map(|_| (rng.index(d) as u32, rng.sign()))
                    .collect();
                Projection { terms }
            })
            .collect();
        (data, projections)
    }

    /// The classic materialize-then-route loop, verbatim from split_node.
    fn classic_best(
        data: &Dataset,
        projections: &[Projection],
        active: &[u32],
        labels: &[u16],
        parent: &[usize],
        n_bins: usize,
        method: SplitMethod,
        rng: &mut Pcg64,
    ) -> Option<(usize, Split)> {
        let mut scratch = SplitScratch::default();
        let mut values = Vec::new();
        let mut best: Option<(usize, Split)> = None;
        for (pi, proj) in projections.iter().enumerate() {
            if proj.is_empty() {
                continue;
            }
            apply_projection(data, proj, active, &mut values);
            let s = best_split(
                method,
                &values,
                labels,
                parent,
                SplitCriterion::Entropy,
                n_bins,
                1,
                rng,
                &mut scratch,
            );
            if let Some(s) = s {
                if best.as_ref().map_or(true, |(_, b)| s.gain > b.gain) {
                    best = Some((pi, s));
                }
            }
        }
        best
    }

    #[test]
    fn fused_matches_classic_winner_and_rng_state() {
        let mut meta = Pcg64::new(0xF15ED);
        for case in 0..30u64 {
            let seed = meta.next_u64();
            let mut rng = Pcg64::new(seed);
            let n_classes = 2 + rng.index(4);
            let n = 64 + rng.index(3000);
            let (data, projections) = setup(&mut rng, n, 12, n_classes);
            let (n_bins, method, routing) = match case % 3 {
                0 => (256, SplitMethod::VectorizedHistogram, Routing::TwoLevel),
                1 => (64, SplitMethod::VectorizedHistogram, Routing::TwoLevel),
                _ => (256, SplitMethod::Histogram, Routing::BinarySearch),
            };
            let active: Vec<u32> = (0..n as u32).filter(|i| i % 3 != 0).collect();
            let mut labels = Vec::new();
            gather_labels(&data, &active, &mut labels);
            let mut parent = vec![0usize; n_classes];
            for &l in &labels {
                parent[l as usize] += 1;
            }

            let mut rng_c = Pcg64::new(seed ^ 0x5EED);
            let mut rng_f = Pcg64::new(seed ^ 0x5EED);
            let classic = classic_best(
                &data,
                &projections,
                &active,
                &labels,
                &parent,
                n_bins,
                method,
                &mut rng_c,
            );
            let mut scratch = SplitScratch::default();
            let fused = best_split_fused(
                &data,
                &projections,
                &active,
                &labels,
                &parent,
                SplitCriterion::Entropy,
                n_bins,
                1,
                routing,
                &mut rng_f,
                &mut scratch,
            );
            match (classic, fused) {
                (None, None) => {}
                (Some((cpi, cs)), Some((fpi, fs))) => {
                    assert_eq!(cpi, fpi, "seed {seed}: winner differs");
                    assert_eq!(
                        cs.threshold.to_bits(),
                        fs.threshold.to_bits(),
                        "seed {seed}"
                    );
                    assert_eq!(cs.gain.to_bits(), fs.gain.to_bits(), "seed {seed}");
                    assert_eq!(cs.n_left, fs.n_left, "seed {seed}");
                    assert_eq!(cs.n_right, fs.n_right, "seed {seed}");
                }
                (c, f) => panic!("seed {seed}: classic {c:?} vs fused {f:?}"),
            }
            // Both paths must have consumed the RNG identically.
            assert_eq!(rng_c.next_u64(), rng_f.next_u64(), "seed {seed}: rng diverged");
        }
    }

    #[test]
    fn binned_axis_fast_path_matches_classic_loop_and_rng() {
        // On a binned store, single-feature ±1 projections take the direct
        // bin-id path in BOTH engines (zero RNG draws each); every other
        // shape falls back to the sampled-boundary pipeline. Mixing the
        // shapes in one candidate set checks winner bit-equality AND that
        // the engines keep consuming the RNG in lockstep around the fast
        // path — the lockstep is what lets `fused` stay a pure perf knob
        // on quantized data.
        let mut rng = Pcg64::new(0xB1A5ED);
        let n = 900;
        let d = 6;
        let n_classes = 3;
        let columns: Vec<Vec<f32>> = (0..d)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let raw_labels: Vec<u16> = (0..n).map(|_| rng.index(n_classes) as u16).collect();
        let data = Dataset::from_columns(columns, raw_labels).quantized(64);
        let projections = vec![
            Projection::axis(0), // fast path, w = +1
            Projection {
                terms: vec![(1, -1.0)], // fast path, w = -1
            },
            Projection {
                terms: vec![(2, 0.5)], // scaled: sampled-boundary path
            },
            Projection {
                terms: vec![(3, 1.0), (4, -1.0)], // oblique: sampled path
            },
            Projection::default(), // empty: skipped by both engines
        ];
        let active: Vec<u32> = (0..n as u32).filter(|i| i % 4 != 1).collect();
        let mut labels = Vec::new();
        gather_labels(&data, &active, &mut labels);
        let mut parent = vec![0usize; n_classes];
        for &l in &labels {
            parent[l as usize] += 1;
        }
        for n_bins in [64usize, 256] {
            let mut rng_c = Pcg64::new(0xC0FFEE);
            let mut rng_f = Pcg64::new(0xC0FFEE);

            // Classic side mirrors the real trainer loop: eligible
            // projections dispatch to `best_split_binned_axis`, the rest
            // materialize and route.
            let mut scratch_c = SplitScratch::default();
            let mut values = Vec::new();
            let mut classic: Option<(usize, Split)> = None;
            for (pi, proj) in projections.iter().enumerate() {
                if proj.is_empty() {
                    continue;
                }
                let s = if let Some((f, negate, bl)) =
                    crate::split::boundaries::binned_axis_plan(&data, proj, n_bins)
                {
                    crate::split::histogram::best_split_binned_axis(
                        &data,
                        f,
                        negate,
                        bl,
                        &active,
                        &labels,
                        &parent,
                        SplitCriterion::Entropy,
                        n_bins,
                        1,
                        &mut scratch_c,
                    )
                } else {
                    apply_projection(&data, proj, &active, &mut values);
                    best_split(
                        SplitMethod::VectorizedHistogram,
                        &values,
                        &labels,
                        &parent,
                        SplitCriterion::Entropy,
                        n_bins,
                        1,
                        &mut rng_c,
                        &mut scratch_c,
                    )
                };
                if let Some(s) = s {
                    if classic.as_ref().map_or(true, |(_, b)| s.gain > b.gain) {
                        classic = Some((pi, s));
                    }
                }
            }

            let mut scratch = SplitScratch::default();
            let fused = best_split_fused(
                &data,
                &projections,
                &active,
                &labels,
                &parent,
                SplitCriterion::Entropy,
                n_bins,
                1,
                Routing::TwoLevel,
                &mut rng_f,
                &mut scratch,
            );
            assert!(scratch.fused_ok[0] && scratch.fused_ok[1], "n_bins {n_bins}");
            let (cpi, cs) = classic.expect("gaussian columns always split");
            let (fpi, fs) = fused.expect("gaussian columns always split");
            assert_eq!(cpi, fpi, "n_bins {n_bins}: winner differs");
            assert_eq!(cs.threshold.to_bits(), fs.threshold.to_bits(), "n_bins {n_bins}");
            assert_eq!(cs.gain.to_bits(), fs.gain.to_bits(), "n_bins {n_bins}");
            assert_eq!(cs.n_left, fs.n_left, "n_bins {n_bins}");
            assert_eq!(cs.n_right, fs.n_right, "n_bins {n_bins}");
            assert_eq!(
                rng_c.next_u64(),
                rng_f.next_u64(),
                "n_bins {n_bins}: rng diverged around the fast path"
            );
        }
    }

    #[test]
    fn constant_projection_is_skipped_like_classic() {
        let n = 500;
        let columns = vec![vec![1.0f32; n], (0..n).map(|i| i as f32).collect()];
        let labels: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
        let data = Dataset::from_columns(columns, labels.clone());
        let projections = vec![Projection::axis(0), Projection::axis(1)];
        let active: Vec<u32> = (0..n as u32).collect();
        let parent = vec![n / 2, n / 2];
        let mut rng = Pcg64::new(3);
        let mut scratch = SplitScratch::default();
        let best = best_split_fused(
            &data,
            &projections,
            &active,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            256,
            1,
            Routing::TwoLevel,
            &mut rng,
            &mut scratch,
        );
        let (pi, s) = best.expect("feature 1 is perfectly splittable");
        assert_eq!(pi, 1, "constant projection must not win");
        assert!(!scratch.fused_ok[0]);
        assert!(scratch.fused_ok[1]);
        assert!(s.gain > 0.0);
    }
}
