//! Histogram split search (YDF baseline + the paper's vectorized variant).
//!
//! Steps (paper Fig 2): sample random-width bin boundaries from the node's
//! values, route every sample into a bin (binary search — baseline — or the
//! branchless two-level compare from [`super::vectorized`]), accumulate
//! per-bin class counts, then scan bin edges with the criterion.
//!
//! Boundaries are sampled *from the data* at random positions (the paper's
//! footnote 1: random-width intervals handle non-uniform value
//! distributions); duplicates are kept — zero-width bins are simply empty
//! and cost nothing in the scan.

use super::criterion::{BoundaryScan, SplitCriterion};
use super::simd;
use super::vectorized::{self, TwoLevelLayout};
use super::{Split, SplitScratch};
use crate::data::{BinLayout, Dataset};
use crate::projection::apply::active_span;
use crate::rng::Pcg64;

/// Bin-routing implementation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Routing {
    /// `std::upper_bound`-style binary search (YDF default).
    BinarySearch,
    /// Two-level 16×16 (256-bin) / 8×8 (64-bin) branchless compare (§4.2).
    /// Falls back to binary search for unsupported bin counts.
    TwoLevel,
}

/// Sample `n_bins − 1` boundaries from `values` at random positions and lay
/// them out (sorted, padded with +∞ to `n_bins` slots) in
/// `scratch.boundaries`; fills `scratch.coarse` when a two-level layout
/// applies. Returns `false` if the feature is constant (no split possible;
/// `scratch.boundaries` is left shorter than `n_bins`, which the fused
/// equivalence tests use to observe "did not fill").
///
/// Thin wrapper over [`super::boundaries::sample_into`] — the single
/// boundary-construction implementation shared with the fused engine.
pub fn build_boundaries(
    values: &[f32],
    n_bins: usize,
    rng: &mut Pcg64,
    scratch: &mut SplitScratch,
) -> bool {
    debug_assert!(n_bins >= 2);
    let b = &mut scratch.boundaries;
    b.clear();
    b.resize(n_bins - 1, 0.0);
    let ok = super::boundaries::sample_into(b, values.len(), rng, |i| values[i], || {
        let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
        for &v in values {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        (lo, hi)
    });
    if !ok {
        return false; // constant feature: no split possible
    }
    b.push(f32::INFINITY); // pad to n_bins slots
    if let Some(layout) = TwoLevelLayout::for_bins(n_bins) {
        vectorized::build_coarse(b, layout, &mut scratch.coarse);
    }
    true
}

/// Route one value by binary search over the real boundaries:
/// `bin = #{ b : b <= v }`.
///
/// Note: rust's `partition_point` is a *branchless* (cmov) binary search —
/// already stronger than the `std::upper_bound` baseline the paper
/// measures against. [`route_upper_bound_branchy`] reproduces that branchy
/// baseline for the Fig 6 comparison.
#[inline]
pub fn route_binary_search(v: f32, boundaries: &[f32], n_real: usize) -> usize {
    boundaries[..n_real].partition_point(|&b| b <= v)
}

/// Classic branchy `std::upper_bound`: the YDF baseline of §4.2, with a
/// data-dependent taken/not-taken branch per level (≈8 levels at 256 bins,
/// each predicted ~50% — the pipeline stalls the paper vectorizes away).
#[inline]
pub fn route_upper_bound_branchy(v: f32, boundaries: &[f32], n_real: usize) -> usize {
    let b = &boundaries[..n_real];
    let mut lo = 0usize;
    let mut len = b.len();
    while len > 0 {
        let half = len / 2;
        let mid = lo + half;
        // Deliberate data-dependent branch (libstdc++ upper_bound shape).
        if b[mid] <= v {
            lo = mid + 1;
            len -= half + 1;
        } else {
            len = half;
        }
    }
    lo
}

/// Fill the `n_bins × n_classes` count table in `scratch.counts`.
/// `boundaries`/`coarse` must be prepared by [`build_boundaries`].
///
/// Labels are range-checked here in every build (not just debug): the
/// fast fill loops index `counts[bin * n_classes + label]` unchecked, and
/// a silently corrupt table is contagious under sibling subtraction.
pub fn fill_histogram(
    values: &[f32],
    labels: &[u16],
    n_bins: usize,
    n_classes: usize,
    routing: Routing,
    scratch: &mut SplitScratch,
) {
    super::check_labels(labels, n_classes);
    let counts = &mut scratch.counts;
    counts.clear();
    counts.resize(n_bins * n_classes, 0);
    let n_real = n_bins - 1;
    let layout = TwoLevelLayout::for_bins(n_bins);
    match (routing, layout) {
        (Routing::TwoLevel, Some(layout)) => {
            vectorized::fill_two_level(
                values,
                labels,
                &scratch.boundaries,
                &scratch.coarse,
                layout,
                n_classes,
                counts,
            );
        }
        _ if n_bins <= super::scan::SCAN_MAX_BINS => {
            // Paper §4.2: linear scan beats binary search up to ~16-32 bins.
            super::scan::fill_scan(values, labels, &scratch.boundaries, n_bins, n_classes, counts);
        }
        _ => {
            // The vector lower-bound kernel wants the table padded with +∞
            // to the next power of two (its fixed-trip search probes those
            // slots). `boundaries` is ours here, so pad in place and
            // restore the documented `n_bins` length afterwards — the
            // retention capture checks it.
            let boundaries = &mut scratch.boundaries;
            let p2 = n_real.next_power_of_two();
            let orig_len = boundaries.len();
            if orig_len < p2 {
                boundaries.resize(p2, f32::INFINITY);
            }
            fill_lower_bound(values, labels, boundaries, n_real, n_classes, counts);
            boundaries.truncate(orig_len);
        }
    }
}

/// Fill a count table by lower-bound routing: route [`simd::ROUTE_CHUNK`]
/// values at a time through the runtime-dispatched kernel into a stack
/// buffer, then scatter the counts (the scatter is a read-modify-write
/// with intra-chunk conflicts, so it stays scalar). Shared by the classic
/// binary-search fill arm above and the fused engine's fallback arm.
///
/// `boundaries` needs `n_real.next_power_of_two()` +∞-padded slots for the
/// vector path; shorter tables take the (bit-identical) scalar route.
pub(super) fn fill_lower_bound(
    values: &[f32],
    labels: &[u16],
    boundaries: &[f32],
    n_real: usize,
    n_classes: usize,
    counts: &mut [u32],
) {
    let mut bins = [0u32; simd::ROUTE_CHUNK];
    for (vchunk, lchunk) in values
        .chunks(simd::ROUTE_CHUNK)
        .zip(labels.chunks(simd::ROUTE_CHUNK))
    {
        let routed = &mut bins[..vchunk.len()];
        simd::route_lower_bound_block(vchunk, boundaries, n_real, routed);
        for (&bin, &l) in routed.iter().zip(lchunk) {
            counts[bin as usize * n_classes + l as usize] += 1;
        }
    }
}

/// Scan bin edges for the best split. `scratch.counts`/`boundaries` must be
/// filled. Threshold for edge `k` is `boundaries[k]` (left ⟺ `v < b[k]`).
pub fn best_edge(
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    scratch: &SplitScratch,
) -> Option<Split> {
    best_edge_in(
        parent_counts,
        criterion,
        n_bins,
        min_leaf,
        &scratch.counts,
        &scratch.boundaries,
    )
}

/// [`best_edge`] over caller-provided buffers — the fused engine keeps one
/// `(counts, boundaries)` segment per projection and scans each in turn.
pub fn best_edge_in(
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    counts: &[u32],
    boundaries: &[f32],
) -> Option<Split> {
    let n_classes = parent_counts.len();
    let n_real = n_bins - 1;
    let mut scan = BoundaryScan::new(criterion, parent_counts);
    let mut best: Option<Split> = None;
    let n = scan.n_total();
    for k in 0..n_real {
        scan.push_bin(&counts[k * n_classes..(k + 1) * n_classes]);
        if let Some(gain) = scan.gain_here(min_leaf) {
            if gain > 1e-12 && best.map_or(true, |b| gain > b.gain) {
                best = Some(Split {
                    threshold: boundaries[k],
                    gain,
                    n_left: scan.n_left,
                    n_right: n - scan.n_left,
                });
            }
        }
    }
    best
}

/// Scan a `p × n_bins × n_classes` stack of per-projection count tables
/// for the winning `(projection index, split)` — the scan half of the
/// sibling-subtraction path, also phase 3 of the fused engine. `ok[pi]`
/// gates projections with no usable boundaries (empty or constant).
/// Tie-breaking matches the classic per-projection search loop: the first
/// strictly-greater gain wins, so both callers stay bit-identical to it.
pub fn best_edge_over_tables(
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    ok: &[bool],
    counts: &[u32],
    boundaries: &[f32],
) -> Option<(usize, Split)> {
    let n_classes = parent_counts.len();
    let stride = n_bins * n_classes;
    debug_assert_eq!(counts.len(), ok.len() * stride);
    debug_assert_eq!(boundaries.len(), ok.len() * n_bins);
    let mut best: Option<(usize, Split)> = None;
    for (pi, &usable) in ok.iter().enumerate() {
        if !usable {
            continue;
        }
        let c = &counts[pi * stride..(pi + 1) * stride];
        let b = &boundaries[pi * n_bins..(pi + 1) * n_bins];
        if let Some(s) = best_edge_in(parent_counts, criterion, n_bins, min_leaf, c, b) {
            if best.as_ref().map_or(true, |(_, x)| s.gain > x.gain) {
                best = Some((pi, s));
            }
        }
    }
    best
}

/// Sibling-histogram subtraction: derive one child's count tables from
/// the parent's minus the other child's. Exact — the two children
/// partition the parent's active set, so for identical boundaries every
/// bin count is additive. `saturating_sub` turns a corrupt parent table
/// into a clamped (and loudly wrong downstream) sibling table instead of
/// a wrapped-around one; [`super::check_labels`] at the fill entry points
/// keeps such corruption from arising silently in the first place.
pub fn subtract_tables(parent: &[u32], child: &[u32], out: &mut Vec<u32>) {
    debug_assert_eq!(parent.len(), child.len());
    out.clear();
    out.resize(parent.len(), 0);
    simd::subtract_saturating(parent, child, out);
}

/// Sharded-histogram merge: add one shard's partial count tables into the
/// accumulator, element-wise. Exact — shards partition the node's active
/// rows, every table cell is a u32 sum of disjoint contributions, so any
/// merge order reproduces the single-store fill bit-for-bit. The SIMD
/// `add_u32` lane kernel is the mirror image of [`subtract_tables`]'s
/// `subtract_u32`.
pub fn merge_tables(acc: &mut [u32], other: &[u32]) {
    debug_assert_eq!(acc.len(), other.len());
    simd::add_in_place(acc, other);
}

/// Reduce per-shard partial tables tree-structured (pairwise by shard
/// index: 0+1, 2+3, … then halves again) into `partials[0]`, returning it.
/// The pairing order is fixed by shard index so the reduction shape is
/// deterministic; bitwise the result is order-independent anyway (u32 adds
/// commute exactly). Empty input yields an empty table.
pub fn merge_shard_tables(mut partials: Vec<Vec<u32>>) -> Vec<u32> {
    while partials.len() > 1 {
        let mut next = Vec::with_capacity(partials.len().div_ceil(2));
        let mut it = partials.into_iter();
        while let Some(mut left) = it.next() {
            if let Some(right) = it.next() {
                merge_tables(&mut left, &right);
            }
            next.push(left);
        }
        partials = next;
    }
    partials.pop().unwrap_or_default()
}

/// Full histogram split search (boundaries → fill → scan).
#[allow(clippy::too_many_arguments)]
pub fn best_split_histogram(
    values: &[f32],
    labels: &[u16],
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    rng: &mut Pcg64,
    scratch: &mut SplitScratch,
    routing: Routing,
) -> Option<Split> {
    debug_assert_eq!(values.len(), labels.len());
    if values.len() < 2 {
        return None;
    }
    if !build_boundaries(values, n_bins, rng, scratch) {
        return None;
    }
    fill_histogram(
        values,
        labels,
        n_bins,
        parent_counts.len(),
        routing,
        scratch,
    );
    best_edge(parent_counts, criterion, n_bins, min_leaf, scratch)
}

/// Binned-axis fast path (the quantized tier's "no float gather, no
/// boundary build" search): for a projection that passed
/// [`super::boundaries::binned_axis_plan`], derive the boundaries from the
/// feature's bin layout, accumulate the stored `u8` bin ids straight into
/// the count table, and scan. Consumes NO RNG — the fused engine mirrors
/// this exactly, so the classic/fused stream-parity contract holds.
///
/// `scratch.boundaries` / `scratch.counts` are left exactly as
/// [`build_boundaries`] + [`fill_histogram`] would leave them for the
/// dequantized values: the retention capture copies this state, and the
/// sibling machinery later re-fills it by float routing — bit-equality
/// between the two fill styles is what keeps subtraction exact.
#[allow(clippy::too_many_arguments)]
pub fn best_split_binned_axis(
    data: &Dataset,
    feature: usize,
    negate: bool,
    layout: &BinLayout,
    active: &[u32],
    labels: &[u16],
    parent_counts: &[usize],
    criterion: SplitCriterion,
    n_bins: usize,
    min_leaf: usize,
    scratch: &mut SplitScratch,
) -> Option<Split> {
    debug_assert_eq!(active.len(), labels.len());
    if active.len() < 2 {
        return None;
    }
    let n_classes = parent_counts.len();
    super::check_labels(labels, n_classes);
    let b = &mut scratch.boundaries;
    b.clear();
    b.resize(n_bins, 0.0);
    super::boundaries::layout_boundaries_into(b, layout, negate);
    if let Some(tl) = TwoLevelLayout::for_bins(n_bins) {
        vectorized::build_coarse(b, tl, &mut scratch.coarse);
    }
    let counts = &mut scratch.counts;
    counts.clear();
    counts.resize(n_bins * n_classes, 0);
    accumulate_bin_ids(data, feature, negate, layout.n_bins(), active, labels, n_classes, counts);
    best_edge(parent_counts, criterion, n_bins, min_leaf, scratch)
}

/// Accumulate stored bin ids straight into a count table — the shared
/// inner loop of the binned fast path (the classic entry above and the
/// fused engine's phase 2). `l` is the layout's bin count: stored ids are
/// `< l` (validated at load/quantize time), and negation maps id `b` to
/// `l − 1 − b` — the same bin binary search assigns the dequantized
/// `−reps[b]`. The caller has already range-checked `labels`.
#[allow(clippy::too_many_arguments)]
pub(super) fn accumulate_bin_ids(
    data: &Dataset,
    feature: usize,
    negate: bool,
    l: usize,
    active: &[u32],
    labels: &[u16],
    n_classes: usize,
    counts: &mut [u32],
) {
    // One loop for both orientations: bin = off + sign·id, with
    // (off, sign) = (l−1, −1) when negated and (0, +1) otherwise. This is
    // the single scalar reference the SIMD routing kernels pin against;
    // the count scatter itself stays scalar — it is a read-modify-write
    // with conflicting bins, and EXPERIMENTS.md §Perf records that
    // splitting it into sub-histograms hurts.
    let (off, sign) = if negate {
        (l as isize - 1, -1isize)
    } else {
        (0, 1)
    };
    // Chunk views never cross shard members, so walk maximal same-shard
    // runs of the active set (one run — the whole set — on unsharded
    // stores). Counts are order-invariant u32 adds, so the run walk is
    // bit-identical to the single-span loop.
    let mut s = 0usize;
    while s < active.len() {
        let e = data.shard_run_end(active, s);
        let run = &active[s..e];
        let span = active_span(run);
        let lo = span.start as u32;
        let bins = data.bin_chunk(feature, span);
        for (&i, &lab) in run.iter().zip(&labels[s..e]) {
            let bin = (off + sign * bins[(i - lo) as usize] as isize) as usize;
            counts[bin * n_classes + lab as usize] += 1;
        }
        s = e;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::split::testutil::{counts_of, gaussian_node};

    fn scratch_with_boundaries(bounds: &[f32], n_bins: usize) -> SplitScratch {
        let mut s = SplitScratch::default();
        s.boundaries = bounds.to_vec();
        s.boundaries.push(f32::INFINITY);
        assert_eq!(s.boundaries.len(), n_bins);
        if let Some(layout) = TwoLevelLayout::for_bins(n_bins) {
            vectorized::build_coarse(&s.boundaries, layout, &mut s.coarse);
        }
        s
    }

    #[test]
    fn binary_search_routing_basics() {
        let bounds = [1.0f32, 2.0, 3.0];
        assert_eq!(route_binary_search(0.5, &bounds, 3), 0);
        assert_eq!(route_binary_search(1.0, &bounds, 3), 1); // b <= v counts
        assert_eq!(route_binary_search(2.5, &bounds, 3), 2);
        assert_eq!(route_binary_search(99.0, &bounds, 3), 3);
    }

    #[test]
    fn two_bin_boundaries_keep_the_sampled_value() {
        // Regression: with n_bins == 2 there is a single sampled boundary,
        // so the "all sampled boundaries identical" degeneracy check was
        // trivially true and the sampled value was ALWAYS discarded for the
        // min/max fallback. A sampled boundary that separates the data must
        // be kept.
        let values = [0.0f32, 10.0, 10.0, 10.0];
        let mut kept_sampled = 0usize;
        for seed in 0..32 {
            let mut rng = Pcg64::new(seed);
            let mut scratch = SplitScratch::default();
            assert!(build_boundaries(&values, 2, &mut rng, &mut scratch));
            let b = scratch.boundaries[0];
            if b == 10.0 {
                // Sampled 10.0 separates ({0.0} | {10.0,10.0,10.0}): kept.
                kept_sampled += 1;
            } else {
                // Sampled 0.0 cannot separate (nothing < 0.0): the min/max
                // fallback boundary is the midpoint.
                assert_eq!(b, 5.0, "seed {seed}");
            }
            assert_eq!(scratch.boundaries[1], f32::INFINITY);
            // Either way the boundary must realize a split of this data.
            let below = values.iter().filter(|&&v| v < b).count();
            assert!(below > 0 && below < values.len(), "seed {seed}: b = {b}");
        }
        assert!(
            kept_sampled > 0,
            "sampled boundary was never kept across 32 seeds — degenerate \
             check is discarding valid single boundaries again"
        );
    }

    #[test]
    fn collapsed_multi_bin_boundaries_kept_when_separating() {
        // All sampled boundaries collapse onto 5.0 (the overwhelmingly
        // common value) but 5.0 still separates the lone 0.0: the sampled
        // boundaries must survive, not be resampled on a min/max grid.
        let mut values = vec![5.0f32; 400];
        values[0] = 0.0;
        // With 3 sampled boundaries from 400 values, P(all == 5.0) is high;
        // retry seeds until the collapse case is exercised.
        let mut collapsed: Option<SplitScratch> = None;
        for seed in 0..16 {
            let mut r = Pcg64::new(seed);
            let mut s = SplitScratch::default();
            assert!(build_boundaries(&values, 4, &mut r, &mut s));
            if s.boundaries[..3].iter().all(|&b| b == 5.0) {
                collapsed = Some(s);
                break;
            }
        }
        let scratch = collapsed.expect("no seed collapsed all sampled boundaries");
        let below = values.iter().filter(|&&v| v < scratch.boundaries[0]).count();
        assert_eq!(below, 1);
        // Constant data still reports unsplittable.
        let mut rng = Pcg64::new(3);
        let mut s = SplitScratch::default();
        assert!(!build_boundaries(&[7.0; 50], 4, &mut rng, &mut s));
    }

    #[test]
    fn fill_counts_sum_to_n() {
        let mut rng = Pcg64::new(5);
        let (values, labels) = gaussian_node(&mut rng, 500, 1.0);
        let mut scratch = SplitScratch::default();
        assert!(build_boundaries(&values, 256, &mut rng, &mut scratch));
        for routing in [Routing::BinarySearch, Routing::TwoLevel] {
            fill_histogram(&values, &labels, 256, 2, routing, &mut scratch);
            let total: u32 = scratch.counts.iter().sum();
            assert_eq!(total as usize, values.len(), "{routing:?}");
        }
    }

    #[test]
    fn separable_data_found_by_histogram() {
        // Two point masses: boundaries are sampled from data values, so the
        // edge at +1.0 (left ⟺ v < 1.0) realizes the perfect split.
        let n = 400;
        let values: Vec<f32> = (0..n)
            .map(|i| if i % 2 == 0 { -1.0 } else { 1.0 })
            .collect();
        let labels: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
        let parent = counts_of(&labels, 2);
        let mut rng = Pcg64::new(6);
        let mut scratch = SplitScratch::default();
        let s = best_split_histogram(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            256,
            1,
            &mut rng,
            &mut scratch,
            Routing::BinarySearch,
        )
        .unwrap();
        assert_eq!(s.n_left, n / 2);
        assert!((s.gain - std::f64::consts::LN_2).abs() < 1e-9);
        assert!(s.threshold > -1.0 && s.threshold <= 1.0);
    }

    #[test]
    fn constant_feature_no_split() {
        let values = vec![2.5f32; 100];
        let labels: Vec<u16> = (0..100).map(|i| (i % 2) as u16).collect();
        let parent = counts_of(&labels, 2);
        let mut rng = Pcg64::new(7);
        let mut scratch = SplitScratch::default();
        assert!(best_split_histogram(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            256,
            1,
            &mut rng,
            &mut scratch,
            Routing::BinarySearch
        )
        .is_none());
    }

    #[test]
    fn degenerate_boundary_sample_falls_back_to_range() {
        // Values heavily concentrated at one point but not constant: random
        // boundary sampling may pick all-equal boundaries; the fallback must
        // still find the split.
        let mut values = vec![0.0f32; 199];
        values.push(10.0);
        let mut labels = vec![0u16; 199];
        labels.push(1);
        let parent = counts_of(&labels, 2);
        let mut rng = Pcg64::new(8);
        let mut scratch = SplitScratch::default();
        let s = best_split_histogram(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            256,
            1,
            &mut rng,
            &mut scratch,
            Routing::BinarySearch,
        );
        let s = s.expect("fallback boundaries should separate 0 from 10");
        assert_eq!(s.n_left, 199);
        assert_eq!(s.n_right, 1);
    }

    #[test]
    fn threshold_partitions_match_reported_counts() {
        let mut rng = Pcg64::new(9);
        let mut scratch = SplitScratch::default();
        for _ in 0..50 {
            let n = 20 + rng.index(2000);
            let (values, labels) = gaussian_node(&mut rng, n, 1.2);
            let parent = counts_of(&labels, 2);
            for routing in [Routing::BinarySearch, Routing::TwoLevel] {
                if let Some(s) = best_split_histogram(
                    &values,
                    &labels,
                    &parent,
                    SplitCriterion::Entropy,
                    256,
                    1,
                    &mut rng,
                    &mut scratch,
                    routing,
                ) {
                    let n_left = values.iter().filter(|&&v| v < s.threshold).count();
                    assert_eq!(n_left, s.n_left, "{routing:?}");
                    assert_eq!(n - n_left, s.n_right, "{routing:?}");
                }
            }
        }
    }

    #[test]
    fn sixty_four_bin_variant_works() {
        let mut rng = Pcg64::new(10);
        let (values, labels) = gaussian_node(&mut rng, 3000, 1.5);
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        let a = best_split_histogram(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            64,
            1,
            &mut rng,
            &mut scratch,
            Routing::TwoLevel,
        )
        .unwrap();
        assert!(a.gain > 0.1);
    }

    #[test]
    fn subtract_then_scan_is_pinned_to_direct_fill() {
        // 4 bins with boundaries at 0,1,2. The left child occupies bins
        // 0..=2 with an empty bin (3) and a class-count tie in bin 0
        // ([1,1]); the right child is everything at 2.5/3.5. The
        // subtraction path must reproduce the direct-fill tables — and
        // therefore the scan's winner — bit-for-bit.
        let n_bins = 4;
        let mut scratch = scratch_with_boundaries(&[0.0, 1.0, 2.0], n_bins);
        let boundaries = scratch.boundaries.clone();
        let left_vals = [-1.0f32, -1.0, 0.5, 0.5, 1.5, 1.5];
        let left_labels = [0u16, 1, 0, 0, 1, 1];
        let right_vals = [2.5f32, 2.5, 3.5];
        let right_labels = [0u16, 1, 0];
        let parent_vals: Vec<f32> = left_vals.iter().chain(&right_vals).copied().collect();
        let parent_labels: Vec<u16> =
            left_labels.iter().chain(&right_labels).copied().collect();

        fill_histogram(
            &parent_vals,
            &parent_labels,
            n_bins,
            2,
            Routing::BinarySearch,
            &mut scratch,
        );
        let parent_table = scratch.counts.clone();
        fill_histogram(
            &left_vals,
            &left_labels,
            n_bins,
            2,
            Routing::BinarySearch,
            &mut scratch,
        );
        let left_table = scratch.counts.clone();
        // Left child's table has an empty bin and a tied bin.
        assert_eq!(left_table, vec![1, 1, 2, 0, 0, 2, 0, 0]);
        fill_histogram(
            &right_vals,
            &right_labels,
            n_bins,
            2,
            Routing::BinarySearch,
            &mut scratch,
        );
        let right_direct = scratch.counts.clone();

        let mut derived = Vec::new();
        subtract_tables(&parent_table, &left_table, &mut derived);
        assert_eq!(derived, right_direct, "subtraction must equal direct fill");
        let mut derived_left = Vec::new();
        subtract_tables(&parent_table, &right_direct, &mut derived_left);
        assert_eq!(derived_left, left_table, "subtraction is symmetric");

        // The scan over the derived left table picks the same edge, with
        // bit-identical gain, as over the direct-fill table.
        let parent_counts = counts_of(&left_labels, 2);
        let ok = [true];
        let scan = |t: &[u32]| {
            best_edge_over_tables(
                &parent_counts,
                SplitCriterion::Entropy,
                n_bins,
                1,
                &ok,
                t,
                &boundaries,
            )
        };
        let a = scan(&derived_left).expect("left child has a positive-gain edge");
        let b = scan(&left_table).unwrap();
        assert_eq!(a.0, b.0);
        assert_eq!(a.1.threshold.to_bits(), b.1.threshold.to_bits());
        assert_eq!(a.1.gain.to_bits(), b.1.gain.to_bits());
        assert_eq!((a.1.n_left, a.1.n_right), (b.1.n_left, b.1.n_right));
        // Bin 0 is a pure class tie, so the winning edge is at 1.0 (bins
        // 0..=1 vs bin 2), not at the tied boundary.
        assert_eq!(a.1.threshold, 1.0);
        assert_eq!(a.1.n_left, 4);

        // Saturating subtraction: a corrupt parent bin below the child's
        // count clamps to zero instead of wrapping to u32::MAX.
        let mut corrupt = parent_table.clone();
        corrupt[0] = 0;
        subtract_tables(&corrupt, &left_table, &mut derived);
        assert_eq!(derived[0], 0);
    }

    #[test]
    fn merge_equals_single_fill() {
        // Per-shard partial tables merged (in any tree shape) must equal
        // the single fill over the concatenated rows bit-for-bit — the
        // exactness that makes sharded training byte-identical.
        let n_bins = 4;
        let mut scratch = scratch_with_boundaries(&[0.0, 1.0, 2.0], n_bins);
        let vals_a = [-1.0f32, 0.5, 1.5, 2.5];
        let labs_a = [0u16, 1, 0, 1];
        let vals_b = [0.5f32, 0.5, 3.5];
        let labs_b = [1u16, 0, 0];
        let all_vals: Vec<f32> = vals_a.iter().chain(&vals_b).copied().collect();
        let all_labs: Vec<u16> = labs_a.iter().chain(&labs_b).copied().collect();
        fill_histogram(&all_vals, &all_labs, n_bins, 2, Routing::BinarySearch, &mut scratch);
        let whole = scratch.counts.clone();
        fill_histogram(&vals_a, &labs_a, n_bins, 2, Routing::BinarySearch, &mut scratch);
        let pa = scratch.counts.clone();
        fill_histogram(&vals_b, &labs_b, n_bins, 2, Routing::BinarySearch, &mut scratch);
        let pb = scratch.counts.clone();
        let merged = merge_shard_tables(vec![pa.clone(), pb.clone()]);
        assert_eq!(merged, whole);
        // Odd shard counts and empty shards reduce to the same table.
        let zero = vec![0u32; whole.len()];
        let merged4 = merge_shard_tables(vec![pa, zero, pb]);
        assert_eq!(merged4, whole);
        assert!(merge_shard_tables(Vec::new()).is_empty());
    }

    #[test]
    #[should_panic(expected = "label out of range")]
    fn out_of_range_label_is_a_checked_error() {
        // Promoted from a debug_assert: must fire in release builds too —
        // a bad label would otherwise corrupt a neighboring bin's counts,
        // and subtraction would propagate the damage to the sibling.
        let mut scratch = scratch_with_boundaries(&[0.0, 1.0, 2.0], 4);
        let values = [0.5f32, 1.5];
        let labels = [0u16, 7]; // label 7 with n_classes = 2
        fill_histogram(&values, &labels, 4, 2, Routing::BinarySearch, &mut scratch);
    }

    #[test]
    fn prebuilt_boundaries_scan_picks_best_edge() {
        // 4 bins, boundaries at 0,1,2; best split of the labels is at 1.0.
        let mut scratch = scratch_with_boundaries(&[0.0, 1.0, 2.0], 4);
        let values = [-0.5f32, -0.5, 0.5, 0.5, 1.5, 1.5, 2.5, 2.5];
        let labels = [0u16, 0, 0, 0, 1, 1, 1, 1];
        fill_histogram(&values, &labels, 4, 2, Routing::BinarySearch, &mut scratch);
        let parent = counts_of(&labels, 2);
        let s = best_edge(&parent, SplitCriterion::Entropy, 4, 1, &scratch).unwrap();
        assert_eq!(s.threshold, 1.0);
        assert_eq!(s.n_left, 4);
    }

    #[test]
    fn binned_axis_direct_accumulate_is_pinned_to_float_routing() {
        // The fast path's count table must be bit-identical to routing the
        // dequantized floats through the same layout-derived boundaries —
        // that identity is what lets inherited (float-routed) fills and
        // direct u8 fills feed the same subtraction without drift.
        use crate::data::Dataset;
        use crate::projection::Projection;
        use crate::split::boundaries::{binned_axis_plan, layout_boundaries_into};
        let mut rng = Pcg64::new(0xD12EC7);
        let n = 800;
        let values: Vec<f32> = (0..n)
            .map(|_| {
                if rng.bernoulli(0.3) {
                    rng.index(4) as f32
                } else {
                    rng.normal() as f32
                }
            })
            .collect();
        let labels: Vec<u16> = (0..n).map(|i| (i % 3) as u16).collect();
        let float = Dataset::from_columns(vec![values], labels.clone());
        let q = float.quantized(64);
        let active: Vec<u32> = (0..n as u32).filter(|i| i % 5 != 0).collect();
        let mut node_labels = Vec::new();
        crate::projection::apply::gather_labels(&q, &active, &mut node_labels);
        let parent = counts_of(&node_labels, 3);
        let n_bins = 256;
        for w in [1.0f32, -1.0] {
            let proj = Projection {
                terms: vec![(0, w)],
            };
            let (f, negate, layout) =
                binned_axis_plan(&q, &proj, n_bins).expect("axis ±1 on a binned store");
            let mut scratch = SplitScratch::default();
            let direct = best_split_binned_axis(
                &q,
                f,
                negate,
                layout,
                &active,
                &node_labels,
                &parent,
                SplitCriterion::Entropy,
                n_bins,
                1,
                &mut scratch,
            );
            // Reference: dequantize the projection, route by binary search
            // over the identical plan boundaries, same scan.
            let mut ref_scratch = SplitScratch::default();
            ref_scratch.boundaries = vec![0.0; n_bins];
            layout_boundaries_into(&mut ref_scratch.boundaries, layout, negate);
            if let Some(tl) = TwoLevelLayout::for_bins(n_bins) {
                vectorized::build_coarse(&ref_scratch.boundaries, tl, &mut ref_scratch.coarse);
            }
            let mut vals = Vec::new();
            crate::projection::apply::apply_projection(&q, &proj, &active, &mut vals);
            fill_histogram(
                &vals,
                &node_labels,
                n_bins,
                3,
                Routing::BinarySearch,
                &mut ref_scratch,
            );
            assert_eq!(scratch.counts, ref_scratch.counts, "w = {w}");
            let reference = best_edge(&parent, SplitCriterion::Entropy, n_bins, 1, &ref_scratch);
            match (direct, reference) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!(a.threshold.to_bits(), b.threshold.to_bits(), "w = {w}");
                    assert_eq!(a.gain.to_bits(), b.gain.to_bits(), "w = {w}");
                    assert_eq!((a.n_left, a.n_right), (b.n_left, b.n_right), "w = {w}");
                }
                (a, b) => panic!("w = {w}: direct {a:?} vs float-routed {b:?}"),
            }
            // And the reported counts partition the dequantized values.
            if let Some(s) = direct {
                let n_left = vals.iter().filter(|&&v| v < s.threshold).count();
                assert_eq!(n_left, s.n_left, "w = {w}");
            }
        }
    }
}
