//! Exact (sort-based) split search.
//!
//! Sort the node's (value, label) pairs and evaluate the criterion at every
//! boundary between distinct values — the split YDF's "exact" mode and
//! Ranger's in-node sorting compute. `O(n log n)` dominated by the sort;
//! for tiny nodes (the bulk of a to-purity tree's node *count*, §4.1) we use
//! an unguarded insertion sort, the same trick `std::sort` implementations
//! lean on and the reason sorting beats histograms at small `n` (Fig 3).

use super::criterion::{BoundaryScan, SplitCriterion};
use super::{Split, SplitScratch};

/// Below this size, insertion sort beats pdqsort's general machinery.
const INSERTION_SORT_MAX: usize = 48;

/// Sort (value,label) pairs in place by value.
#[inline]
pub fn sort_pairs(pairs: &mut [(f32, u16)]) {
    if pairs.len() <= INSERTION_SORT_MAX {
        insertion_sort(pairs);
    } else {
        pairs.sort_unstable_by(|a, b| a.0.total_cmp(&b.0));
    }
}

/// Insertion sort with an "unguarded" inner loop: the minimum element is
/// first swapped to the front so inner-loop comparisons need no bounds
/// check — 2 branches/element on nearly-sorted data.
fn insertion_sort(pairs: &mut [(f32, u16)]) {
    let n = pairs.len();
    if n < 2 {
        return;
    }
    // Place the minimum at index 0 as a sentinel.
    let mut min_i = 0;
    for i in 1..n {
        if pairs[i].0 < pairs[min_i].0 {
            min_i = i;
        }
    }
    pairs.swap(0, min_i);
    for i in 2..n {
        let x = pairs[i];
        let mut j = i;
        // Unguarded: pairs[0] is <= x, so j-1 never underflows past it.
        while pairs[j - 1].0 > x.0 {
            pairs[j] = pairs[j - 1];
            j -= 1;
        }
        pairs[j] = x;
    }
}

/// Best exact split of `values`/`labels`.
///
/// Returns `None` when no boundary with positive gain exists (constant
/// feature, pure node, or min_leaf infeasible).
pub fn best_split_exact(
    values: &[f32],
    labels: &[u16],
    parent_counts: &[usize],
    criterion: SplitCriterion,
    min_leaf: usize,
    scratch: &mut SplitScratch,
) -> Option<Split> {
    debug_assert_eq!(values.len(), labels.len());
    let n = values.len();
    if n < 2 {
        return None;
    }
    let pairs = &mut scratch.pairs;
    pairs.clear();
    pairs.extend(values.iter().copied().zip(labels.iter().copied()));
    sort_pairs(pairs);

    let mut scan = BoundaryScan::new(criterion, parent_counts);
    let mut best: Option<Split> = None;
    for i in 0..n - 1 {
        scan.push(pairs[i].1);
        // Only between distinct values is a threshold realizable.
        if pairs[i].0 < pairs[i + 1].0 {
            if let Some(gain) = scan.gain_here(min_leaf) {
                if gain > 1e-12 && best.map_or(true, |b| gain > b.gain) {
                    // Midpoint threshold; guard against f32 rounding making
                    // it equal to the left value.
                    let mut t = 0.5 * (pairs[i].0 + pairs[i + 1].0);
                    if t <= pairs[i].0 {
                        t = pairs[i + 1].0;
                    }
                    best = Some(Split {
                        threshold: t,
                        gain,
                        n_left: i + 1,
                        n_right: n - i - 1,
                    });
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::split::testutil::{counts_of, gaussian_node};
    use crate::rng::Pcg64;

    #[test]
    fn insertion_sort_matches_std() {
        let mut rng = Pcg64::new(1);
        for n in [0usize, 1, 2, 3, 7, 16, 48] {
            let mut a: Vec<(f32, u16)> = (0..n)
                .map(|i| (rng.normal() as f32, (i % 3) as u16))
                .collect();
            let mut b = a.clone();
            insertion_sort(&mut a);
            b.sort_unstable_by(|x, y| x.0.total_cmp(&y.0));
            assert_eq!(
                a.iter().map(|p| p.0).collect::<Vec<_>>(),
                b.iter().map(|p| p.0).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn separable_data_gets_perfect_split() {
        let values = vec![-2.0f32, -1.5, -1.0, 1.0, 1.5, 2.0];
        let labels = vec![0u16, 0, 0, 1, 1, 1];
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        let s = best_split_exact(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            1,
            &mut scratch,
        )
        .unwrap();
        assert_eq!(s.n_left, 3);
        assert_eq!(s.n_right, 3);
        assert!(s.threshold > -1.0 && s.threshold <= 1.0, "{}", s.threshold);
        assert!((s.gain - std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn constant_feature_no_split() {
        let values = vec![3.0f32; 10];
        let labels: Vec<u16> = (0..10).map(|i| (i % 2) as u16).collect();
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        assert!(best_split_exact(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            1,
            &mut scratch
        )
        .is_none());
    }

    #[test]
    fn pure_node_no_split() {
        let values = vec![1.0f32, 2.0, 3.0];
        let labels = vec![1u16, 1, 1];
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        assert!(best_split_exact(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            1,
            &mut scratch
        )
        .is_none());
    }

    #[test]
    fn unsorted_input_handled() {
        let values = vec![2.0f32, -2.0, 1.5, -1.5];
        let labels = vec![1u16, 0, 1, 0];
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        let s = best_split_exact(
            &values,
            &labels,
            &parent,
            SplitCriterion::Gini,
            1,
            &mut scratch,
        )
        .unwrap();
        assert_eq!(s.n_left, 2);
        assert!((s.gain - 0.5).abs() < 1e-12);
    }

    #[test]
    fn threshold_actually_partitions_reported_counts() {
        // Property: applying the returned threshold reproduces n_left/n_right.
        let mut rng = Pcg64::new(42);
        let mut scratch = SplitScratch::default();
        for trial in 0..100 {
            let n = 2 + rng.index(200);
            let (values, labels) = gaussian_node(&mut rng, n, 1.0);
            let parent = counts_of(&labels, 2);
            if let Some(s) = best_split_exact(
                &values,
                &labels,
                &parent,
                SplitCriterion::Entropy,
                1,
                &mut scratch,
            ) {
                let n_left = values.iter().filter(|&&v| v < s.threshold).count();
                assert_eq!(n_left, s.n_left, "trial {trial}");
                assert_eq!(n - n_left, s.n_right, "trial {trial}");
                assert!(s.gain > 0.0);
            }
        }
    }

    #[test]
    fn duplicate_values_never_split_within_ties() {
        let values = vec![1.0f32, 1.0, 1.0, 2.0, 2.0];
        let labels = vec![0u16, 1, 0, 1, 1];
        let parent = counts_of(&labels, 2);
        let mut scratch = SplitScratch::default();
        let s = best_split_exact(
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            1,
            &mut scratch,
        )
        .unwrap();
        // The only realizable boundary is between 1.0 and 2.0.
        assert_eq!(s.n_left, 3);
        assert!(s.threshold > 1.0 && s.threshold <= 2.0);
    }
}
