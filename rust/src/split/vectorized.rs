//! Branchless two-level bin routing (paper §4.2).
//!
//! YDF routes each sample into one of 256 bins with `std::upper_bound` — a
//! binary search whose 8 branches are taken with ~equal probability,
//! guaranteeing mispredictions and pipeline stalls. The paper replaces it
//! with two 16-wide vector compares over a *two-level deterministic skip
//! list*: a coarse vector holding every 16th boundary selects a group of
//! 16, a second compare within the group selects the bin. 7 instructions on
//! AVX-512. The block fill below routes through the runtime-dispatched
//! kernels in [`super::simd`] (explicit AVX-512/AVX2/NEON `std::arch` code
//! picked per-CPU, no `-C target-cpu=native` required); the portable
//! single-value routes in this file are branch-free scalar code that doubles
//! as the dispatch oracle. A 64-bin 8×8 variant mirrors the paper's AVX-2
//! version.
//!
//! Routing semantics match the binary-search baseline exactly:
//! `bin(v) = #{ boundaries b : b <= v }` — verified bit-for-bit by the
//! equivalence tests below and exercised again by the Fig 6 bench.

use super::simd;

/// Geometry of a two-level layout: `groups × group` bins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TwoLevelLayout {
    /// Number of coarse groups (= lanes of the coarse compare).
    pub groups: usize,
    /// Bins per group (= lanes of the fine compare).
    pub group_size: usize,
}

impl TwoLevelLayout {
    /// The layouts the paper ships: 256 = 16×16 (AVX-512), 64 = 8×8 (AVX-2).
    pub fn for_bins(n_bins: usize) -> Option<TwoLevelLayout> {
        match n_bins {
            256 => Some(TwoLevelLayout {
                groups: 16,
                group_size: 16,
            }),
            64 => Some(TwoLevelLayout {
                groups: 8,
                group_size: 8,
            }),
            _ => None,
        }
    }
}

/// Build the coarse vector: every `group_size`-th boundary, i.e. the last
/// boundary of each group. `boundaries` must be sorted and padded with +∞
/// to `groups·group_size` slots. The final coarse slot is the +∞ pad, so
/// the group count can never overflow.
pub fn build_coarse(boundaries: &[f32], layout: TwoLevelLayout, coarse: &mut Vec<f32>) {
    debug_assert_eq!(boundaries.len(), layout.groups * layout.group_size);
    coarse.clear();
    coarse.resize(layout.groups, 0.0);
    super::boundaries::coarse_into(boundaries, layout, coarse);
}

/// Route one value through the 16×16 structure. `coarse` and `fine` must be
/// the arrays prepared by [`build_coarse`] (fine = full padded boundaries).
///
/// Single-value convenience over the portable route — the block fill paths
/// go through the runtime-dispatched kernels in [`super::simd`] instead
/// (AVX-512 gets the paper's 7-instruction sequence there without needing
/// `-C target-cpu=native`).
#[inline(always)]
pub fn route_16x16(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    route_16x16_portable(v, coarse, fine)
}

/// Portable branch-free routing (also the test oracle for the SIMD path).
#[inline(always)]
pub fn route_16x16_portable(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    debug_assert!(coarse.len() >= 16 && fine.len() >= 256);
    // Coarse compare: how many group-end boundaries are <= v. Fixed 16-lane
    // loop, no data-dependent branch — compiles to one vector compare + mask
    // count. (`&coarse[..16]` pins the bounds so LLVM drops the checks.)
    let c = &coarse[..16];
    // Build the 16-lane compare as a bitmask so LLVM lowers it to
    // vcmpleps + kmovw + popcnt (the paper's 7-instruction sequence); a
    // plain `+=` reduction makes LLVM extract all 16 mask bits one by one.
    let mut m = 0u32;
    for j in 0..16 {
        m |= ((c[j] <= v) as u32) << j;
    }
    let g = m.count_ones();
    // v = +∞ also satisfies the +∞ pad compares; both clamps are branchless
    // (cmov) and no-ops for finite v.
    let base = (g as usize).min(15) * 16;
    // Fine compare within the selected group. Pinning `fine` to 256 slots
    // lets LLVM prove `base + 16 <= 256` and drop the bounds-check branch.
    let fine = &fine[..256];
    let grp = &fine[base..base + 16];
    let mut m2 = 0u32;
    for j in 0..16 {
        m2 |= ((grp[j] <= v) as u32) << j;
    }
    (base + m2.count_ones() as usize).min(255)
}

/// 64-bin 8×8 variant (paper's AVX-2 implementation — the vector version
/// lives in [`super::simd`]; this is the single-value portable route).
#[inline(always)]
pub fn route_8x8(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    route_8x8_portable(v, coarse, fine)
}

/// Portable branch-free 8×8 routing (oracle for the SIMD path).
#[inline(always)]
pub fn route_8x8_portable(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    debug_assert!(coarse.len() >= 8 && fine.len() >= 64);
    let c = &coarse[..8];
    let mut m = 0u32;
    for j in 0..8 {
        m |= ((c[j] <= v) as u32) << j;
    }
    let base = (m.count_ones() as usize).min(7) * 8;
    let grp = &fine[base..base + 8];
    let mut m2 = 0u32;
    for j in 0..8 {
        m2 |= ((grp[j] <= v) as u32) << j;
    }
    (base + m2.count_ones() as usize).min(63)
}

/// Fill `counts[bin·n_classes + label]` for all samples using two-level
/// routing. The two-class case (every performance dataset in the paper) has
/// a dedicated loop so the count update is a single indexed add with a
/// strength-reduced offset.
pub fn fill_two_level(
    values: &[f32],
    labels: &[u16],
    boundaries: &[f32],
    coarse: &[f32],
    layout: TwoLevelLayout,
    n_classes: usize,
    counts: &mut [u32],
) {
    debug_assert_eq!(counts.len(), layout.groups * layout.group_size * n_classes);
    // The specialized 2-class loops index `counts[bin * 2 + label]`: a label
    // >= n_classes would silently corrupt the *next bin's* class slots in
    // release builds (no bounds check catches it, the buffer is big enough).
    debug_assert!(
        labels.iter().all(|&l| (l as usize) < n_classes),
        "label out of range for {n_classes} classes"
    );
    // Route a whole chunk through the runtime-dispatched kernel into a
    // stack buffer, then scatter the counts. The chunk amortizes the
    // indirect kernel call; the scatter itself stays scalar by necessity —
    // `counts[bin·nc + l] += 1` is a read-modify-write with intra-chunk
    // conflicts (and the §Perf note below rules out splitting it).
    let route: fn(&[f32], &[f32], &[f32], &mut [u32]) = match (layout.groups, layout.group_size) {
        (16, 16) => simd::route16_block,
        (8, 8) => simd::route8_block,
        _ => {
            for (&v, &l) in values.iter().zip(labels) {
                let bin = route_generic(v, boundaries, coarse, layout);
                counts[bin * n_classes + l as usize] += 1;
            }
            return;
        }
    };
    let mut bins = [0u32; simd::ROUTE_CHUNK];
    for (vchunk, lchunk) in values
        .chunks(simd::ROUTE_CHUNK)
        .zip(labels.chunks(simd::ROUTE_CHUNK))
    {
        let routed = &mut bins[..vchunk.len()];
        route(vchunk, coarse, boundaries, routed);
        if n_classes == 2 {
            // §Perf note: a 4-way unroll with split sub-histograms was
            // tried and *hurt* (-40%: four inlined 16-lane routes blow the
            // register budget); the simple chunked route + single scatter
            // below is the fastest variant measured — see EXPERIMENTS.md
            // §Perf.
            for (&bin, &l) in routed.iter().zip(lchunk) {
                counts[bin as usize * 2 + l as usize] += 1;
            }
        } else {
            for (&bin, &l) in routed.iter().zip(lchunk) {
                counts[bin as usize * n_classes + l as usize] += 1;
            }
        }
    }
}

/// Generic-layout routing (kept for completeness / tests with odd layouts).
#[inline]
pub fn route_generic(v: f32, boundaries: &[f32], coarse: &[f32], layout: TwoLevelLayout) -> usize {
    let mut g = 0usize;
    for j in 0..layout.groups {
        g += (coarse[j] <= v) as usize;
    }
    let base = g.min(layout.groups - 1) * layout.group_size;
    let mut k = 0usize;
    for j in 0..layout.group_size {
        k += (boundaries[base + j] <= v) as usize;
    }
    (base + k).min(layout.groups * layout.group_size - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::split::histogram::route_binary_search;

    /// Sorted random boundaries padded to `n_bins` slots with +inf.
    fn padded_boundaries(rng: &mut Pcg64, n_bins: usize) -> Vec<f32> {
        let mut b: Vec<f32> = (0..n_bins - 1).map(|_| rng.normal() as f32).collect();
        b.sort_unstable_by(f32::total_cmp);
        b.push(f32::INFINITY);
        b
    }

    #[test]
    fn equivalent_to_binary_search_256() {
        let mut rng = Pcg64::new(21);
        for _ in 0..20 {
            let layout = TwoLevelLayout::for_bins(256).unwrap();
            let b = padded_boundaries(&mut rng, 256);
            let mut coarse = Vec::new();
            build_coarse(&b, layout, &mut coarse);
            for _ in 0..2000 {
                let v = (rng.normal() * 2.0) as f32;
                let want = route_binary_search(v, &b, 255);
                assert_eq!(route_16x16(v, &coarse, &b), want, "v={v}");
                assert_eq!(route_generic(v, &b, &coarse, layout), want);
            }
        }
    }

    #[test]
    fn equivalent_to_binary_search_64() {
        let mut rng = Pcg64::new(22);
        for _ in 0..20 {
            let layout = TwoLevelLayout::for_bins(64).unwrap();
            let b = padded_boundaries(&mut rng, 64);
            let mut coarse = Vec::new();
            build_coarse(&b, layout, &mut coarse);
            for _ in 0..2000 {
                let v = (rng.normal() * 2.0) as f32;
                assert_eq!(
                    route_8x8(v, &coarse, &b),
                    route_binary_search(v, &b, 63),
                    "v={v}"
                );
            }
        }
    }

    #[test]
    fn boundary_values_route_right_of_their_boundary() {
        // bin(v) counts b <= v, so v exactly equal to a boundary belongs to
        // the bin *after* it — same convention as upper_bound in YDF.
        let mut b: Vec<f32> = (0..255).map(|i| i as f32).collect();
        b.push(f32::INFINITY);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        assert_eq!(route_16x16(0.0, &coarse, &b), 1);
        assert_eq!(route_16x16(-0.5, &coarse, &b), 0);
        assert_eq!(route_16x16(254.0, &coarse, &b), 255);
        assert_eq!(route_16x16(1e9, &coarse, &b), 255);
    }

    #[test]
    fn duplicate_boundaries_skip_bins() {
        let mut b = vec![1.0f32; 255];
        for (i, x) in b.iter_mut().enumerate().take(100) {
            *x = i as f32 * 0.001; // first 100 distinct, rest all 1.0
        }
        b.sort_unstable_by(f32::total_cmp);
        b.push(f32::INFINITY);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        let mut rng = Pcg64::new(23);
        for _ in 0..2000 {
            let v = (rng.normal() * 2.0) as f32;
            assert_eq!(route_16x16(v, &coarse, &b), route_binary_search(v, &b, 255));
        }
        // Any v >= 1.0 lands in the last bin (all 155 dup boundaries <= v).
        assert_eq!(route_16x16(1.0, &coarse, &b), 255);
    }

    #[test]
    fn nan_and_extremes_do_not_crash_or_overflow() {
        let mut rng = Pcg64::new(24);
        let b = padded_boundaries(&mut rng, 256);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        for v in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::MAX, f32::MIN] {
            let bin = route_16x16(v, &coarse, &b);
            assert!(bin < 256, "v={v} bin={bin}");
            assert_eq!(bin, route_binary_search(v, &b, 255), "v={v}");
        }
    }

    #[test]
    fn fill_matches_scalar_reference() {
        let mut rng = Pcg64::new(25);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let b = padded_boundaries(&mut rng, 256);
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        let n = 5000;
        let values: Vec<f32> = (0..n).map(|_| (rng.normal() * 1.5) as f32).collect();
        let labels: Vec<u16> = (0..n).map(|_| rng.index(3) as u16).collect();
        let mut got = vec![0u32; 256 * 3];
        fill_two_level(&values, &labels, &b, &coarse, layout, 3, &mut got);
        let mut want = vec![0u32; 256 * 3];
        for (&v, &l) in values.iter().zip(&labels) {
            want[route_binary_search(v, &b, 255) * 3 + l as usize] += 1;
        }
        assert_eq!(got, want);
    }

    #[test]
    fn fill_handles_empty_bins_single_class_and_chunk_remainders() {
        // Every sample in one bin (all other bins empty), one class only,
        // at lengths straddling the route-chunk boundary: the chunked
        // route + scatter must put exactly n counts in exactly one slot.
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut b: Vec<f32> = (0..255).map(|i| i as f32).collect();
        b.push(f32::INFINITY);
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        for n in [0usize, 1, 7, 33, 255, 256, 257, 1000] {
            let values = vec![42.25f32; n];
            let labels = vec![0u16; n];
            let mut got = vec![0u32; 256 * 2];
            fill_two_level(&values, &labels, &b, &coarse, layout, 2, &mut got);
            let mut want = vec![0u32; 256 * 2];
            want[43 * 2] = n as u32; // boundaries 0..=42 are <= 42.25
            assert_eq!(got, want, "n={n}");
        }
    }
}
