//! Branchless two-level bin routing (paper §4.2).
//!
//! YDF routes each sample into one of 256 bins with `std::upper_bound` — a
//! binary search whose 8 branches are taken with ~equal probability,
//! guaranteeing mispredictions and pipeline stalls. The paper replaces it
//! with two 16-wide vector compares over a *two-level deterministic skip
//! list*: a coarse vector holding every 16th boundary selects a group of
//! 16, a second compare within the group selects the bin. 7 instructions on
//! AVX-512; here the same algorithm is written over fixed 16-lane arrays
//! with branch-free lane counts, which LLVM auto-vectorizes to `vcmpps` +
//! mask-popcount under `-C target-cpu=native` (and remains branch-free on
//! any target). A 64-bin 8×8 variant mirrors the paper's AVX-2 version.
//!
//! Routing semantics match the binary-search baseline exactly:
//! `bin(v) = #{ boundaries b : b <= v }` — verified bit-for-bit by the
//! equivalence tests below and exercised again by the Fig 6 bench.

/// Geometry of a two-level layout: `groups × group` bins.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TwoLevelLayout {
    /// Number of coarse groups (= lanes of the coarse compare).
    pub groups: usize,
    /// Bins per group (= lanes of the fine compare).
    pub group_size: usize,
}

impl TwoLevelLayout {
    /// The layouts the paper ships: 256 = 16×16 (AVX-512), 64 = 8×8 (AVX-2).
    pub fn for_bins(n_bins: usize) -> Option<TwoLevelLayout> {
        match n_bins {
            256 => Some(TwoLevelLayout {
                groups: 16,
                group_size: 16,
            }),
            64 => Some(TwoLevelLayout {
                groups: 8,
                group_size: 8,
            }),
            _ => None,
        }
    }
}

/// Build the coarse vector: every `group_size`-th boundary, i.e. the last
/// boundary of each group. `boundaries` must be sorted and padded with +∞
/// to `groups·group_size` slots. The final coarse slot is the +∞ pad, so
/// the group count can never overflow.
pub fn build_coarse(boundaries: &[f32], layout: TwoLevelLayout, coarse: &mut Vec<f32>) {
    debug_assert_eq!(boundaries.len(), layout.groups * layout.group_size);
    coarse.clear();
    coarse.resize(layout.groups, 0.0);
    super::boundaries::coarse_into(boundaries, layout, coarse);
}

/// Route one value through the 16×16 structure. `coarse` and `fine` must be
/// the arrays prepared by [`build_coarse`] (fine = full padded boundaries).
///
/// On AVX-512 targets this compiles to the paper's 7-instruction sequence
/// (broadcast, 2 × {16-lane compare → mask → popcount}, address math); the
/// portable fallback is branch-free scalar code and doubles as the oracle
/// for the SIMD path in tests.
#[inline(always)]
pub fn route_16x16(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    #[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
    {
        route_16x16_avx512(v, coarse, fine)
    }
    #[cfg(not(all(target_arch = "x86_64", target_feature = "avx512f")))]
    {
        route_16x16_portable(v, coarse, fine)
    }
}

/// The AVX-512 implementation of §4.2: two `vcmpps` + `popcnt` pairs.
#[cfg(all(target_arch = "x86_64", target_feature = "avx512f"))]
#[inline(always)]
pub fn route_16x16_avx512(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    use core::arch::x86_64::*;
    assert!(coarse.len() >= 16 && fine.len() >= 256);
    // SAFETY: lengths asserted above; loads are unaligned-tolerant
    // (_mm512_loadu_ps); `base <= 240` so `fine[base..base+16]` is in
    // bounds; the compare-mask semantics (b <= v, false on NaN) match the
    // portable path, verified by `avx512_matches_portable`.
    unsafe {
        let vv = _mm512_set1_ps(v);
        let cb = _mm512_loadu_ps(coarse.as_ptr());
        let g = (_mm512_cmp_ps_mask::<_CMP_LE_OQ>(cb, vv).count_ones() as usize).min(15);
        let base = g * 16;
        let grp = _mm512_loadu_ps(fine.as_ptr().add(base));
        let k = _mm512_cmp_ps_mask::<_CMP_LE_OQ>(grp, vv).count_ones() as usize;
        (base + k).min(255)
    }
}

/// Portable branch-free routing (also the test oracle for the SIMD path).
#[inline(always)]
pub fn route_16x16_portable(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    debug_assert!(coarse.len() >= 16 && fine.len() >= 256);
    // Coarse compare: how many group-end boundaries are <= v. Fixed 16-lane
    // loop, no data-dependent branch — compiles to one vector compare + mask
    // count. (`&coarse[..16]` pins the bounds so LLVM drops the checks.)
    let c = &coarse[..16];
    // Build the 16-lane compare as a bitmask so LLVM lowers it to
    // vcmpleps + kmovw + popcnt (the paper's 7-instruction sequence); a
    // plain `+=` reduction makes LLVM extract all 16 mask bits one by one.
    let mut m = 0u32;
    for j in 0..16 {
        m |= ((c[j] <= v) as u32) << j;
    }
    let g = m.count_ones();
    // v = +∞ also satisfies the +∞ pad compares; both clamps are branchless
    // (cmov) and no-ops for finite v.
    let base = (g as usize).min(15) * 16;
    // Fine compare within the selected group. Pinning `fine` to 256 slots
    // lets LLVM prove `base + 16 <= 256` and drop the bounds-check branch.
    let fine = &fine[..256];
    let grp = &fine[base..base + 16];
    let mut m2 = 0u32;
    for j in 0..16 {
        m2 |= ((grp[j] <= v) as u32) << j;
    }
    (base + m2.count_ones() as usize).min(255)
}

/// 64-bin 8×8 variant (paper's AVX-2 implementation).
#[inline(always)]
pub fn route_8x8(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    #[cfg(all(target_arch = "x86_64", target_feature = "avx512f", target_feature = "avx512vl"))]
    {
        use core::arch::x86_64::*;
        assert!(coarse.len() >= 8 && fine.len() >= 64);
        // SAFETY: as in route_16x16_avx512; 256-bit lanes for 8-wide groups.
        unsafe {
            let vv = _mm256_set1_ps(v);
            let cb = _mm256_loadu_ps(coarse.as_ptr());
            let g = (_mm256_cmp_ps_mask::<_CMP_LE_OQ>(cb, vv).count_ones() as usize).min(7);
            let base = g * 8;
            let grp = _mm256_loadu_ps(fine.as_ptr().add(base));
            let k = _mm256_cmp_ps_mask::<_CMP_LE_OQ>(grp, vv).count_ones() as usize;
            return (base + k).min(63);
        }
    }
    #[cfg(not(all(target_arch = "x86_64", target_feature = "avx512f", target_feature = "avx512vl")))]
    {
        route_8x8_portable(v, coarse, fine)
    }
}

/// Portable branch-free 8×8 routing (oracle for the SIMD path).
#[inline(always)]
pub fn route_8x8_portable(v: f32, coarse: &[f32], fine: &[f32]) -> usize {
    debug_assert!(coarse.len() >= 8 && fine.len() >= 64);
    let c = &coarse[..8];
    let mut m = 0u32;
    for j in 0..8 {
        m |= ((c[j] <= v) as u32) << j;
    }
    let base = (m.count_ones() as usize).min(7) * 8;
    let grp = &fine[base..base + 8];
    let mut m2 = 0u32;
    for j in 0..8 {
        m2 |= ((grp[j] <= v) as u32) << j;
    }
    (base + m2.count_ones() as usize).min(63)
}

/// Fill `counts[bin·n_classes + label]` for all samples using two-level
/// routing. The two-class case (every performance dataset in the paper) has
/// a dedicated loop so the count update is a single indexed add with a
/// strength-reduced offset.
pub fn fill_two_level(
    values: &[f32],
    labels: &[u16],
    boundaries: &[f32],
    coarse: &[f32],
    layout: TwoLevelLayout,
    n_classes: usize,
    counts: &mut [u32],
) {
    debug_assert_eq!(counts.len(), layout.groups * layout.group_size * n_classes);
    // The specialized 2-class loops index `counts[bin * 2 + label]`: a label
    // >= n_classes would silently corrupt the *next bin's* class slots in
    // release builds (no bounds check catches it, the buffer is big enough).
    debug_assert!(
        labels.iter().all(|&l| (l as usize) < n_classes),
        "label out of range for {n_classes} classes"
    );
    match (layout.groups, n_classes) {
        (16, 2) => {
            // §Perf note: a 4-way unroll with split sub-histograms was
            // tried and *hurt* (-40%: four inlined 16-lane routes blow the
            // register budget); the simple fused loop below is the fastest
            // variant measured — see EXPERIMENTS.md §Perf.
            for (&v, &l) in values.iter().zip(labels) {
                let bin = route_16x16(v, coarse, boundaries);
                counts[bin * 2 + l as usize] += 1;
            }
        }
        (16, _) => {
            for (&v, &l) in values.iter().zip(labels) {
                let bin = route_16x16(v, coarse, boundaries);
                counts[bin * n_classes + l as usize] += 1;
            }
        }
        (8, 2) => {
            for (&v, &l) in values.iter().zip(labels) {
                let bin = route_8x8(v, coarse, boundaries);
                counts[bin * 2 + l as usize] += 1;
            }
        }
        _ => {
            for (&v, &l) in values.iter().zip(labels) {
                let bin = route_generic(v, boundaries, coarse, layout);
                counts[bin * n_classes + l as usize] += 1;
            }
        }
    }
}

/// Generic-layout routing (kept for completeness / tests with odd layouts).
#[inline]
pub fn route_generic(v: f32, boundaries: &[f32], coarse: &[f32], layout: TwoLevelLayout) -> usize {
    let mut g = 0usize;
    for j in 0..layout.groups {
        g += (coarse[j] <= v) as usize;
    }
    let base = g.min(layout.groups - 1) * layout.group_size;
    let mut k = 0usize;
    for j in 0..layout.group_size {
        k += (boundaries[base + j] <= v) as usize;
    }
    (base + k).min(layout.groups * layout.group_size - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::split::histogram::route_binary_search;

    /// Sorted random boundaries padded to `n_bins` slots with +inf.
    fn padded_boundaries(rng: &mut Pcg64, n_bins: usize) -> Vec<f32> {
        let mut b: Vec<f32> = (0..n_bins - 1).map(|_| rng.normal() as f32).collect();
        b.sort_unstable_by(f32::total_cmp);
        b.push(f32::INFINITY);
        b
    }

    #[test]
    fn equivalent_to_binary_search_256() {
        let mut rng = Pcg64::new(21);
        for _ in 0..20 {
            let layout = TwoLevelLayout::for_bins(256).unwrap();
            let b = padded_boundaries(&mut rng, 256);
            let mut coarse = Vec::new();
            build_coarse(&b, layout, &mut coarse);
            for _ in 0..2000 {
                let v = (rng.normal() * 2.0) as f32;
                let want = route_binary_search(v, &b, 255);
                assert_eq!(route_16x16(v, &coarse, &b), want, "v={v}");
                assert_eq!(route_generic(v, &b, &coarse, layout), want);
            }
        }
    }

    #[test]
    fn equivalent_to_binary_search_64() {
        let mut rng = Pcg64::new(22);
        for _ in 0..20 {
            let layout = TwoLevelLayout::for_bins(64).unwrap();
            let b = padded_boundaries(&mut rng, 64);
            let mut coarse = Vec::new();
            build_coarse(&b, layout, &mut coarse);
            for _ in 0..2000 {
                let v = (rng.normal() * 2.0) as f32;
                assert_eq!(
                    route_8x8(v, &coarse, &b),
                    route_binary_search(v, &b, 63),
                    "v={v}"
                );
            }
        }
    }

    #[test]
    fn boundary_values_route_right_of_their_boundary() {
        // bin(v) counts b <= v, so v exactly equal to a boundary belongs to
        // the bin *after* it — same convention as upper_bound in YDF.
        let mut b: Vec<f32> = (0..255).map(|i| i as f32).collect();
        b.push(f32::INFINITY);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        assert_eq!(route_16x16(0.0, &coarse, &b), 1);
        assert_eq!(route_16x16(-0.5, &coarse, &b), 0);
        assert_eq!(route_16x16(254.0, &coarse, &b), 255);
        assert_eq!(route_16x16(1e9, &coarse, &b), 255);
    }

    #[test]
    fn duplicate_boundaries_skip_bins() {
        let mut b = vec![1.0f32; 255];
        for (i, x) in b.iter_mut().enumerate().take(100) {
            *x = i as f32 * 0.001; // first 100 distinct, rest all 1.0
        }
        b.sort_unstable_by(f32::total_cmp);
        b.push(f32::INFINITY);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        let mut rng = Pcg64::new(23);
        for _ in 0..2000 {
            let v = (rng.normal() * 2.0) as f32;
            assert_eq!(route_16x16(v, &coarse, &b), route_binary_search(v, &b, 255));
        }
        // Any v >= 1.0 lands in the last bin (all 155 dup boundaries <= v).
        assert_eq!(route_16x16(1.0, &coarse, &b), 255);
    }

    #[test]
    fn nan_and_extremes_do_not_crash_or_overflow() {
        let mut rng = Pcg64::new(24);
        let b = padded_boundaries(&mut rng, 256);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        for v in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::MAX, f32::MIN] {
            let bin = route_16x16(v, &coarse, &b);
            assert!(bin < 256, "v={v} bin={bin}");
            assert_eq!(bin, route_binary_search(v, &b, 255), "v={v}");
        }
    }

    #[test]
    fn fill_matches_scalar_reference() {
        let mut rng = Pcg64::new(25);
        let layout = TwoLevelLayout::for_bins(256).unwrap();
        let b = padded_boundaries(&mut rng, 256);
        let mut coarse = Vec::new();
        build_coarse(&b, layout, &mut coarse);
        let n = 5000;
        let values: Vec<f32> = (0..n).map(|_| (rng.normal() * 1.5) as f32).collect();
        let labels: Vec<u16> = (0..n).map(|_| rng.index(3) as u16).collect();
        let mut got = vec![0u32; 256 * 3];
        fill_two_level(&values, &labels, &b, &coarse, layout, 3, &mut got);
        let mut want = vec![0u32; 256 * 3];
        for (&v, &l) in values.iter().zip(&labels) {
            want[route_binary_search(v, &b, 255) * 3 + l as usize] += 1;
        }
        assert_eq!(got, want);
    }
}

#[cfg(all(test, target_arch = "x86_64", target_feature = "avx512f"))]
mod simd_tests {
    use super::*;
    use crate::rng::Pcg64;

    /// The AVX-512 fast path must agree with the portable oracle on random,
    /// boundary-equal, NaN and infinite inputs.
    #[test]
    fn avx512_matches_portable() {
        let mut rng = Pcg64::new(99);
        for _ in 0..10 {
            let mut b: Vec<f32> = (0..255).map(|_| rng.normal() as f32).collect();
            b.sort_unstable_by(f32::total_cmp);
            b.push(f32::INFINITY);
            let layout = TwoLevelLayout::for_bins(256).unwrap();
            let mut coarse = Vec::new();
            build_coarse(&b, layout, &mut coarse);
            for _ in 0..5000 {
                let v = (rng.normal() * 2.0) as f32;
                assert_eq!(
                    route_16x16_avx512(v, &coarse, &b),
                    route_16x16_portable(v, &coarse, &b),
                    "v={v}"
                );
            }
            for v in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, b[0], b[100], b[254]] {
                assert_eq!(
                    route_16x16_avx512(v, &coarse, &b),
                    route_16x16_portable(v, &coarse, &b),
                    "v={v}"
                );
            }
        }
    }
}
