//! Shared histogram-boundary construction (paper §4.1, footnote 1).
//!
//! Both histogram engines — the materializing path
//! ([`super::histogram::build_boundaries`]) and the fused blocked pipeline
//! ([`super::fused`]) — sample `n_bins − 1` random-position boundaries from
//! the node's projected values, sort them, and fall back to range-anchored
//! boundaries when every sampled boundary collapses onto a value that
//! cannot separate the data. Until this module existed, that logic lived
//! as two hand-mirrored copies whose bit-equivalence contract had to be
//! maintained by editing both identically (the PR 2 `n_bins = 2` fix had
//! to be applied twice). Now there is exactly one implementation, generic
//! over how a value is fetched: the materializing path indexes a dense
//! buffer, the fused path projects single rows on demand — the RNG call
//! sequence (`rng.index(n)` per boundary) and every f32 comparison are
//! shared, so the two engines *cannot* drift apart.

use super::vectorized::TwoLevelLayout;
use crate::data::{BinLayout, Dataset};
use crate::projection::Projection;
use crate::rng::Pcg64;

/// Fill `b` (length `n_bins − 1`) with sampled, sorted boundaries.
///
/// * `n` — number of addressable values; boundary positions are drawn as
///   `rng.index(n)`, one draw per slot, in slot order.
/// * `sample(i)` — the i-th value (dense buffer lookup or on-demand
///   projection; must be bit-identical arithmetic between engines).
/// * `min_max()` — full (min, max) of the values, evaluated **only** on
///   the degenerate all-boundaries-equal path so the fused engine never
///   pays a full materialization for the common case.
///
/// Returns `false` when the values are constant (no split possible); `b`
/// contents are unspecified in that case. Otherwise `b` holds sorted
/// boundaries that realize at least one non-trivial partition.
pub fn sample_into(
    b: &mut [f32],
    n: usize,
    rng: &mut Pcg64,
    sample: impl Fn(usize) -> f32,
    min_max: impl FnOnce() -> (f32, f32),
) -> bool {
    let n_real = b.len();
    debug_assert!(n_real >= 1);
    for slot in b.iter_mut() {
        *slot = sample(rng.index(n));
    }
    b.sort_unstable_by(f32::total_cmp);
    if b[0] == b[n_real - 1] {
        // All sampled boundaries collapsed to one value `v`. That is only
        // degenerate when `v` cannot separate the data (`bin 0 = {x < v}`
        // empty or `bin >= 1 = {x >= v}` empty). Note `n_real == 1`
        // (n_bins == 2) lands here trivially — a single sampled boundary
        // must be KEPT when it separates, or small bin counts silently lose
        // the §4.1 sampled-boundary semantics to the min/max fallback.
        let (lo, hi) = min_max();
        if lo == hi {
            return false; // constant feature: no split possible
        }
        if !(lo < b[0] && b[0] <= hi) {
            // The collapsed sampled boundary puts every sample on one side;
            // fall back to min/max-anchored boundaries so a split is still
            // findable (rare but happens on tiny nodes).
            let n_bins = n_real + 1;
            for (i, slot) in b.iter_mut().enumerate() {
                let frac = (i + 1) as f32 / n_bins as f32;
                *slot = lo + (hi - lo) * frac;
            }
        }
    }
    true
}

/// Coarse-vector padding for two-level routing: the last boundary of each
/// group. `boundaries` must be sorted and +∞-padded to
/// `groups · group_size` slots; `coarse` must be `groups` slots. The final
/// coarse slot is the +∞ pad, so the group count can never overflow.
#[inline]
pub fn coarse_into(boundaries: &[f32], layout: TwoLevelLayout, coarse: &mut [f32]) {
    debug_assert_eq!(boundaries.len(), layout.groups * layout.group_size);
    debug_assert_eq!(coarse.len(), layout.groups);
    for (g, c) in coarse.iter_mut().enumerate() {
        *c = boundaries[g * layout.group_size + layout.group_size - 1];
    }
}

/// Binned-axis fast-path eligibility: a candidate projection can skip the
/// float gather AND the boundary sampling when the store is quantized, the
/// projection is a single feature with weight ±1, and that feature's bin
/// layout has `2..=n_bins` bins. Returns `(feature, negate, layout)`.
///
/// A pure function of (store, projection, n_bins) — never of the node's
/// values — so the classic and fused engines make the same call per
/// projection and their RNG streams stay aligned: an eligible projection
/// draws ZERO boundary positions in both engines
/// ([`layout_boundaries_into`] replaces [`sample_into`]).
///
/// The ±1 weight restriction is load-bearing, not cosmetic: `±1 · rep` is
/// exact in f32, so binary-search routing of the dequantized value over
/// the layout-derived boundaries lands in exactly the stored bin (possibly
/// mirrored) — the identity that keeps mixed fill styles (direct u8
/// accumulate, inherited float-routing fills, subtraction A/B) bit-equal.
/// An arbitrary weight could collapse two adjacent `w · rep` products onto
/// one f32 and break that identity.
pub fn binned_axis_plan<'d>(
    data: &'d Dataset,
    proj: &Projection,
    n_bins: usize,
) -> Option<(usize, bool, &'d BinLayout)> {
    let layouts = data.bin_layouts()?;
    let [(f, w)] = proj.terms.as_slice() else {
        return None;
    };
    if *w != 1.0 && *w != -1.0 {
        return None;
    }
    let layout = &layouts[*f as usize];
    let l = layout.n_bins();
    if l < 2 || l > n_bins {
        // One-bin layouts are constant columns (the float path would bail
        // the same way, just after burning RNG draws — so those columns
        // must take the float path to keep the engines' draws aligned…
        // which they do, because this predicate is shared). Layouts wider
        // than the histogram can't map bin ids 1:1 onto histogram bins.
        return None;
    }
    Some((*f as usize, *w < 0.0, layout))
}

/// Layout-derived boundaries for an eligible binned axis projection —
/// zero RNG draws. Fills all slots of `b` (the engines pass their full
/// `n_bins`-slot segment).
///
/// With `w = +1` the boundary between histogram bins `k` and `k+1` is
/// `reps[k+1]`: reps are strictly increasing, so binary-search routing of
/// `reps[b]` (`#{k : boundary[k] <= v}`) yields exactly `b`. With
/// `w = −1` the projected values are `−reps[b]`, so the boundaries are
/// the negated reps reversed (`−reps[L−2−k]`, still increasing) and
/// stored bin `b` routes to `L−1−b`. Slots past the last real boundary
/// are +∞-padded; their edges see `n_right = 0` and are rejected by the
/// scan exactly like the classic single +∞ pad slot.
pub fn layout_boundaries_into(b: &mut [f32], layout: &BinLayout, negate: bool) {
    let reps = layout.reps();
    let l = reps.len();
    debug_assert!((2..=b.len()).contains(&l));
    if negate {
        for (k, slot) in b[..l - 1].iter_mut().enumerate() {
            *slot = -reps[l - 2 - k];
        }
    } else {
        b[..l - 1].copy_from_slice(&reps[1..]);
    }
    for slot in &mut b[l - 1..] {
        *slot = f32::INFINITY;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::split::SplitScratch;

    /// The materializing engine's wrapper and a direct `sample_into` call
    /// over the same dense values must agree bit-for-bit — including the
    /// RNG state left behind. Together with the fused-engine test below,
    /// this pins both engines to this single implementation.
    #[test]
    fn histogram_wrapper_is_the_shared_function() {
        let mut meta = Pcg64::new(0xB0DA);
        for case in 0..40u64 {
            let seed = meta.next_u64();
            let mut rng = Pcg64::new(seed);
            let n = 2 + rng.index(500);
            let n_bins = if case % 2 == 0 { 256 } else { 2 + rng.index(62) };
            let values: Vec<f32> = (0..n)
                .map(|_| {
                    if rng.bernoulli(0.3) {
                        rng.index(3) as f32 // heavy duplicates
                    } else {
                        rng.normal() as f32
                    }
                })
                .collect();

            let mut rng_a = Pcg64::new(seed ^ 0xA);
            let mut rng_b = Pcg64::new(seed ^ 0xA);
            let mut scratch = SplitScratch::default();
            let ok_a = crate::split::histogram::build_boundaries(
                &values,
                n_bins,
                &mut rng_a,
                &mut scratch,
            );
            let mut b = vec![0f32; n_bins - 1];
            let ok_b = sample_into(
                &mut b,
                values.len(),
                &mut rng_b,
                |i| values[i],
                || {
                    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
                    for &v in &values {
                        lo = lo.min(v);
                        hi = hi.max(v);
                    }
                    (lo, hi)
                },
            );
            assert_eq!(ok_a, ok_b, "seed {seed}");
            assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "seed {seed}: rng diverged");
            if ok_a {
                assert_eq!(scratch.boundaries.len(), n_bins, "seed {seed}");
                for (k, (&x, &y)) in scratch.boundaries[..n_bins - 1].iter().zip(&b).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "seed {seed} boundary {k}");
                }
                assert_eq!(scratch.boundaries[n_bins - 1], f32::INFINITY);
            }
        }
    }

    /// The fused engine's per-projection boundary segments must equal the
    /// materializing wrapper's output for the same RNG stream — i.e. both
    /// engines consume this module, not private mirrors.
    #[test]
    fn fused_segments_match_histogram_wrapper() {
        use crate::data::Dataset;
        use crate::projection::apply::{apply_projection, gather_labels};
        use crate::projection::Projection;
        use crate::split::histogram::Routing;
        use crate::split::{best_split_fused, SplitCriterion};

        let mut rng = Pcg64::new(0x5EED5);
        let n = 700;
        let d = 6;
        let columns: Vec<Vec<f32>> = (0..d)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let labels_raw: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
        let data = Dataset::from_columns(columns, labels_raw);
        let projections: Vec<Projection> = (0..4)
            .map(|_| Projection {
                terms: vec![
                    (rng.index(d) as u32, rng.sign()),
                    (rng.index(d) as u32, rng.sign()),
                ],
            })
            .collect();
        let active: Vec<u32> = (0..n as u32).collect();
        let mut labels = Vec::new();
        gather_labels(&data, &active, &mut labels);
        let parent = vec![n / 2, n - n / 2];
        let n_bins = 256;

        let mut rng_f = Pcg64::new(42);
        let mut scratch = SplitScratch::default();
        best_split_fused(
            &data,
            &projections,
            &active,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            n_bins,
            1,
            Routing::TwoLevel,
            &mut rng_f,
            &mut scratch,
        );

        let mut rng_c = Pcg64::new(42);
        let mut ref_scratch = SplitScratch::default();
        let mut values = Vec::new();
        for (pi, proj) in projections.iter().enumerate() {
            apply_projection(&data, proj, &active, &mut values);
            assert!(crate::split::histogram::build_boundaries(
                &values,
                n_bins,
                &mut rng_c,
                &mut ref_scratch,
            ));
            let seg = &scratch.fused_boundaries[pi * n_bins..(pi + 1) * n_bins];
            for (k, (&x, &y)) in ref_scratch.boundaries.iter().zip(seg).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "projection {pi} boundary {k}");
            }
        }
    }

    #[test]
    fn binned_axis_plan_gates_on_shape_weight_and_layout() {
        let columns = vec![
            (0..40).map(|i| (i % 7) as f32).collect::<Vec<f32>>(), // 7 bins
            vec![3.5f32; 40],                                      // constant: 1 bin
            (0..40).map(|i| i as f32).collect(),                   // 40 bins
        ];
        let labels: Vec<u16> = (0..40).map(|i| (i % 2) as u16).collect();
        let float = Dataset::from_columns(columns, labels);
        let q = float.quantized(64);

        // Float stores never plan.
        assert!(binned_axis_plan(&float, &Projection::axis(0), 256).is_none());
        // Single feature, w = +1.
        let (f, neg, layout) = binned_axis_plan(&q, &Projection::axis(0), 256).unwrap();
        assert_eq!((f, neg, layout.n_bins()), (0, false, 7));
        // w = −1 flips.
        let p = Projection {
            terms: vec![(0, -1.0)],
        };
        let (_, neg, _) = binned_axis_plan(&q, &p, 256).unwrap();
        assert!(neg);
        // Non-unit weight, multi-term and empty projections fall back.
        let half = Projection {
            terms: vec![(0, 0.5)],
        };
        assert!(binned_axis_plan(&q, &half, 256).is_none());
        let two = Projection {
            terms: vec![(0, 1.0), (2, -1.0)],
        };
        assert!(binned_axis_plan(&q, &two, 256).is_none());
        assert!(binned_axis_plan(&q, &Projection::default(), 256).is_none());
        // Constant column (one-bin layout) falls back to the float path.
        assert!(binned_axis_plan(&q, &Projection::axis(1), 256).is_none());
        // A layout wider than the histogram can't map ids 1:1.
        assert!(binned_axis_plan(&q, &Projection::axis(2), 16).is_none());
        assert!(binned_axis_plan(&q, &Projection::axis(2), 256).is_some());
    }

    #[test]
    fn layout_boundaries_route_every_rep_to_its_stored_bin() {
        use crate::split::histogram::route_binary_search;
        let mut rng = Pcg64::new(0xB1A5);
        let values: Vec<f32> = (0..500)
            .map(|_| {
                if rng.bernoulli(0.4) {
                    rng.index(5) as f32
                } else {
                    rng.normal() as f32
                }
            })
            .collect();
        for max_bins in [4usize, 16, 64] {
            let layout = BinLayout::fit(&values, max_bins);
            let l = layout.n_bins();
            assert!(l >= 2);
            for n_bins in [l, 64, 256] {
                if l > n_bins {
                    continue;
                }
                for negate in [false, true] {
                    let mut b = vec![0f32; n_bins];
                    layout_boundaries_into(&mut b, &layout, negate);
                    // Real boundaries strictly increasing, tail +∞-padded.
                    for k in 1..l - 1 {
                        assert!(b[k - 1] < b[k], "max_bins {max_bins} negate {negate}");
                    }
                    for &pad in &b[l - 1..] {
                        assert_eq!(pad, f32::INFINITY);
                    }
                    // The routing identity the direct accumulate relies on:
                    // the dequantized value of stored bin `s` routes to `s`
                    // (or its mirror under negation).
                    for s in 0..l {
                        let v = if negate {
                            -layout.rep(s as u8)
                        } else {
                            layout.rep(s as u8)
                        };
                        let routed = route_binary_search(v, &b, n_bins - 1);
                        let expect = if negate { l - 1 - s } else { s };
                        assert_eq!(
                            routed, expect,
                            "max_bins {max_bins} n_bins {n_bins} negate {negate} bin {s}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn coarse_matches_vectorized_builder() {
        let layout = TwoLevelLayout::for_bins(64).unwrap();
        let mut boundaries: Vec<f32> = (0..63).map(|i| i as f32 * 0.5).collect();
        boundaries.push(f32::INFINITY);
        let mut via_vec = Vec::new();
        crate::split::vectorized::build_coarse(&boundaries, layout, &mut via_vec);
        let mut direct = vec![0f32; layout.groups];
        coarse_into(&boundaries, layout, &mut direct);
        assert_eq!(via_vec, direct);
        assert_eq!(direct.last().copied(), Some(f32::INFINITY));
    }
}
