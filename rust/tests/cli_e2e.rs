//! End-to-end CLI tests: drive `soforest::cli::run` exactly as the binary
//! does, including CSV round-trips through the filesystem.

use soforest::cli;
use std::path::PathBuf;

fn argv(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

#[test]
fn train_on_generated_data() {
    cli::run(&argv(&[
        "train",
        "--data",
        "susy:300",
        "--trees",
        "3",
        "--threads",
        "1",
        "--seed",
        "5",
    ]))
    .unwrap();
}

#[test]
fn train_with_growth_modes_and_persisted_thresholds() {
    // Both schedulers train through the CLI; frontier is the default, depth
    // is selectable. The thresholds file (the `calibrate --out` format) is
    // loaded by `--thresholds` instead of re-running calibration.
    let thresholds = tmp("soforest_e2e_thresholds.json");
    soforest::calibrate::save_thresholds(
        &thresholds,
        &soforest::split::SplitThresholds {
            sort_below: 96,
            accel_above: usize::MAX,
        },
        256,
    )
    .unwrap();
    for growth in ["depth", "frontier"] {
        cli::run(&argv(&[
            "train",
            "--data",
            "trunk:300:8",
            "--trees",
            "2",
            "--threads",
            "2",
            "--growth",
            growth,
            "--thresholds",
            thresholds.to_str().unwrap(),
        ]))
        .unwrap();
    }
    std::fs::remove_file(&thresholds).ok();
    // Unknown growth mode is a hard error.
    assert!(cli::run(&argv(&[
        "train", "--data", "trunk:100:8", "--trees", "1", "--growth", "sideways",
    ]))
    .is_err());
}

#[test]
fn train_with_hist_subtraction_flag() {
    // The sibling-subtraction A/B flag parses from the CLI and both values
    // train end-to-end (byte-identity of the forests is enforced by
    // frontier_equivalence.rs; this drives the user-facing surface).
    for sub in ["on", "off"] {
        cli::run(&argv(&[
            "train",
            "--data",
            "trunk:800:8",
            "--trees",
            "1",
            "--threads",
            "2",
            "--sort_below",
            "128",
            "--hist_subtraction",
            sub,
            "--instrument",
        ]))
        .unwrap();
    }
    assert!(cli::run(&argv(&[
        "train", "--data", "trunk:100:8", "--trees", "1", "--hist_subtraction", "sideways",
    ]))
    .is_err());
}

#[test]
fn train_with_instrumentation_and_dynamic_strategy() {
    cli::run(&argv(&[
        "train",
        "--data",
        "trunk:400:16",
        "--trees",
        "2",
        "--threads",
        "1",
        "--strategy",
        "dynamic",
        "--instrument",
        "--sort_below",
        "128",
    ]))
    .unwrap();
}

#[test]
fn eval_reports_holdout_and_rf_baseline() {
    cli::run(&argv(&[
        "eval",
        "--data",
        "trunk:500:8",
        "--trees",
        "5",
        "--threads",
        "1",
        "--test-frac",
        "0.3",
    ]))
    .unwrap();
}

#[test]
fn gen_data_then_train_from_csv() {
    let path = tmp("soforest_e2e_data.csv");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "credit-approval:200",
        "--out",
        path.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        path.to_str().unwrap(),
        "--trees",
        "2",
        "--threads",
        "1",
    ]))
    .unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn might_protocol_runs() {
    cli::run(&argv(&[
        "might",
        "--data",
        "trunk:400:8",
        "--trees",
        "8",
        "--threads",
        "1",
        "--replicates",
        "2",
    ]))
    .unwrap();
}

#[test]
fn config_file_plus_flag_overrides() {
    let cfg_path = tmp("soforest_e2e_cfg.toml");
    std::fs::write(&cfg_path, "n_trees = 2\nstrategy = \"exact\"\nthreads = 1\n").unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        "trunk:200:8",
        "--config",
        cfg_path.to_str().unwrap(),
        "--strategy",
        "histogram", // flag wins over file
    ]))
    .unwrap();
    std::fs::remove_file(cfg_path).ok();
}

#[test]
fn unknown_command_and_flags_error() {
    assert!(cli::run(&argv(&["frobnicate"])).is_err());
    assert!(cli::run(&argv(&["train"])).is_err()); // missing --data
    assert!(cli::run(&argv(&["train", "--data", "nosuchgen:10"])).is_err());
}

#[test]
fn info_and_help_always_succeed() {
    cli::run(&argv(&["help"])).unwrap();
    cli::run(&argv(&["info", "--artifacts", "/nonexistent"])).unwrap();
}

#[test]
fn train_save_predict_roundtrip() {
    let model = tmp("soforest_e2e_model.bin");
    let csv = tmp("soforest_e2e_predict.csv");
    let preds = tmp("soforest_e2e_preds.csv");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:300:8",
        "--out",
        csv.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        csv.to_str().unwrap(),
        "--trees",
        "4",
        "--threads",
        "1",
        "--oob",
        "--out",
        model.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--data",
        csv.to_str().unwrap(),
        "--out",
        preds.to_str().unwrap(),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(&preds).unwrap();
    assert_eq!(text.lines().count(), 301); // header + 300 predictions
    // Mismatched feature count must error.
    assert!(cli::run(&argv(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--data",
        "trunk:50:16",
    ]))
    .is_err());
    for p in [model, csv, preds] {
        std::fs::remove_file(p).ok();
    }
}

/// Train a small model + CSV in temp files, returning their paths.
fn train_model(tag: &str) -> (PathBuf, PathBuf) {
    let model = tmp(&format!("soforest_e2e_{tag}_model.bin"));
    let csv = tmp(&format!("soforest_e2e_{tag}_data.csv"));
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:300:8",
        "--out",
        csv.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        csv.to_str().unwrap(),
        "--trees",
        "4",
        "--threads",
        "1",
        "--out",
        model.to_str().unwrap(),
    ]))
    .unwrap();
    (model, csv)
}

#[test]
fn score_streams_csv_through_model() {
    let (model, csv) = train_model("score");
    let preds = tmp("soforest_e2e_score_preds.csv");
    cli::run(&argv(&[
        "score",
        "--model",
        model.to_str().unwrap(),
        "--data",
        csv.to_str().unwrap(),
        "--block",
        "64",
        "--threads",
        "2",
        "--out",
        preds.to_str().unwrap(),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(&preds).unwrap();
    assert_eq!(text.lines().count(), 301); // header + 300 predictions
    // Generator-spec input flows through the same scorer.
    cli::run(&argv(&[
        "score",
        "--model",
        model.to_str().unwrap(),
        "--data",
        "trunk:200:8",
        "--block",
        "32",
        "--threads",
        "1",
    ]))
    .unwrap();
    // Missing model / wrong width must error.
    assert!(cli::run(&argv(&["score", "--data", csv.to_str().unwrap()])).is_err());
    assert!(cli::run(&argv(&[
        "score",
        "--model",
        model.to_str().unwrap(),
        "--data",
        "trunk:50:16",
    ]))
    .is_err());
    for p in [model, csv, preds] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn serve_answers_tcp_requests_end_to_end() {
    use std::io::{BufRead, BufReader, Write};
    let (model, csv) = train_model("serve");
    let port_file = tmp("soforest_e2e_serve_port");
    std::fs::remove_file(&port_file).ok();
    let model_arg = model.to_str().unwrap().to_string();
    let pf_arg = port_file.to_str().unwrap().to_string();
    let server = std::thread::spawn(move || {
        cli::run(&argv(&[
            "serve",
            "--model",
            &model_arg,
            "--tcp",
            "127.0.0.1:0",
            "--port-file",
            &pf_arg,
            "--max-requests",
            "4",
            "--max-batch",
            "2",
            "--max-wait-us",
            "500",
        ]))
    });
    let mut tries = 0;
    let addr = loop {
        match std::fs::read_to_string(&port_file) {
            Ok(s) if !s.is_empty() => break s,
            _ => {
                tries += 1;
                assert!(tries < 2000, "serve never wrote the port file");
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
    };
    let mut conn = std::net::TcpStream::connect(addr.trim()).unwrap();
    // 3 valid rows (8 features) + 1 malformed: 4 responses, in order.
    conn.write_all(b"0,0,0,0,0,0,0,0\n1,1,1,1,1,1,1,1\nnot,a,row\n2,2,2,2,2,2,2,2\n")
        .unwrap();
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    let answers: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
    assert_eq!(answers.len(), 4, "{answers:?}");
    for (i, a) in answers.iter().enumerate() {
        if i == 2 {
            assert!(a.starts_with("!err"), "{a}");
        } else {
            let class: usize = a.parse().unwrap();
            assert!(class < 2, "{a}");
        }
    }
    server.join().unwrap().unwrap();
    for p in [model, csv, port_file] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn top_once_and_metrics_file_against_a_live_server() {
    // Observability e2e, all through the CLI: a server with --metrics-file,
    // a `top --once` frame polled mid-session (which must not consume a
    // request ticket), and the final exact metrics dump at drain.
    use std::io::{BufRead, BufReader, Write};
    let (model, csv) = train_model("top");
    let port_file = tmp("soforest_e2e_top_port");
    let metrics_file = tmp("soforest_e2e_top_metrics.json");
    std::fs::remove_file(&port_file).ok();
    std::fs::remove_file(&metrics_file).ok();
    let model_arg = model.to_str().unwrap().to_string();
    let pf_arg = port_file.to_str().unwrap().to_string();
    let mf_arg = metrics_file.to_str().unwrap().to_string();
    let server = std::thread::spawn(move || {
        cli::run(&argv(&[
            "serve",
            "--model",
            &model_arg,
            "--tcp",
            "127.0.0.1:0",
            "--port-file",
            &pf_arg,
            "--max-requests",
            "3",
            "--metrics-file",
            &mf_arg,
            "--metrics-interval-ms",
            "100",
            "--log-spans",
        ]))
    });
    let mut tries = 0;
    loop {
        match std::fs::read_to_string(&port_file) {
            Ok(s) if !s.is_empty() => break,
            _ => {
                tries += 1;
                assert!(tries < 2000, "serve never wrote the port file");
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
    }
    let addr = std::fs::read_to_string(&port_file).unwrap();
    let mut conn = std::net::TcpStream::connect(addr.trim()).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    for _ in 0..2 {
        conn.write_all(b"0,0,0,0,0,0,0,0\n").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.trim().parse::<usize>().is_ok(), "{line}");
    }
    // A single `top` frame against the live server. Its `!stats` poll must
    // not eat into the request budget: the third real request below still
    // gets its answer.
    cli::run(&argv(&[
        "top",
        "--port-file",
        port_file.to_str().unwrap(),
        "--once",
    ]))
    .expect("top --once against a live server");
    conn.write_all(b"0,0,0,0,0,0,0,0\n").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(line.trim().parse::<usize>().is_ok(), "{line}");
    // Budget exhausted: the server drains and the CLI returns.
    server.join().unwrap().unwrap();
    // The final metrics dump holds the exact totals: 3 answered requests,
    // and the top poll's connection counted but ticketless.
    let dumped = soforest::serve::ServeStats::from_json_line(
        std::fs::read_to_string(&metrics_file).unwrap().trim(),
    )
    .expect("metrics file JSON");
    assert_eq!(dumped.served, 3);
    assert_eq!(dumped.requests, 3);
    assert!(dumped.conns >= 2, "client + top poll, got {}", dumped.conns);
    assert_eq!(dumped.latency.count, 3);
    for p in [model, csv, port_file, metrics_file] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn migrate_upgrades_v1_models_that_still_load() {
    // Write a model in the legacy v1 layout, check every entry point still
    // reads it, then migrate to v2 and compare predictions.
    let data = soforest::data::synth::generate(
        "trunk:200:8",
        &mut soforest::rng::Pcg64::new(3),
    )
    .unwrap();
    let cfg = soforest::config::ForestConfig {
        n_trees: 3,
        n_threads: 1,
        ..Default::default()
    };
    let forest = soforest::coordinator::train_forest(&data, &cfg, 8);
    let v1_path = tmp("soforest_e2e_v1_model.bin");
    let v2_path = tmp("soforest_e2e_v2_model.bin");
    {
        let f = std::fs::File::create(&v1_path).unwrap();
        let mut w = std::io::BufWriter::new(f);
        soforest::forest::serialize::write_forest_v1(&forest, &mut w).unwrap();
        std::io::Write::flush(&mut w).unwrap();
    }
    cli::run(&argv(&[
        "migrate",
        "--model",
        v1_path.to_str().unwrap(),
        "--out",
        v2_path.to_str().unwrap(),
    ]))
    .unwrap();
    let from_v1 = soforest::forest::serialize::load(&v1_path).unwrap();
    let from_v2 = soforest::forest::serialize::load(&v2_path).unwrap();
    assert_eq!(from_v1.predict(&data), from_v2.predict(&data));
    assert_eq!(forest.predict(&data), from_v2.predict(&data));
    for p in [v1_path, v2_path] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn importance_command_runs() {
    cli::run(&argv(&[
        "importance",
        "--data",
        "sparse-parity:300:8",
        "--trees",
        "8",
        "--threads",
        "1",
        "--repeats",
        "2",
        "--top",
        "4",
    ]))
    .unwrap();
}

#[test]
fn pack_and_train_from_column_file() {
    // gen-data -> CSV -> pack -> .sofc -> train: the full out-of-core
    // round trip through the CLI surface. The packed file must sniff as a
    // column file, train end-to-end on the mapped backend, and produce
    // the same model bytes as training off the CSV directly.
    let csv_path = tmp("soforest_e2e_pack.csv");
    let sofc_path = tmp("soforest_e2e_pack.sofc");
    let model_csv = tmp("soforest_e2e_pack_csv.bin");
    let model_sofc = tmp("soforest_e2e_pack_sofc.bin");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:600:6",
        "--seed",
        "7",
        "--out",
        csv_path.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "pack",
        "--data",
        csv_path.to_str().unwrap(),
        "--out",
        sofc_path.to_str().unwrap(),
    ]))
    .unwrap();
    assert!(soforest::data::colfile::sniff(&sofc_path));
    for (data, model) in [(&csv_path, &model_csv), (&sofc_path, &model_sofc)] {
        cli::run(&argv(&[
            "train",
            "--data",
            data.to_str().unwrap(),
            "--trees",
            "3",
            "--threads",
            "2",
            "--seed",
            "11",
            "--out",
            model.to_str().unwrap(),
        ]))
        .unwrap();
    }
    assert_eq!(
        std::fs::read(&model_csv).unwrap(),
        std::fs::read(&model_sofc).unwrap(),
        "training off the packed column file changed the model bytes"
    );
    // The packed file also predicts through the blocked row-gather path.
    cli::run(&argv(&[
        "predict",
        "--model",
        model_sofc.to_str().unwrap(),
        "--data",
        sofc_path.to_str().unwrap(),
        "--threads",
        "2",
    ]))
    .unwrap();
    for p in [csv_path, sofc_path, model_csv, model_sofc] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn pack_from_generator_spec() {
    let sofc_path = tmp("soforest_e2e_pack_spec.sofc");
    cli::run(&argv(&[
        "pack",
        "--data",
        "sparse-parity:300:8",
        "--seed",
        "3",
        "--out",
        sofc_path.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        sofc_path.to_str().unwrap(),
        "--trees",
        "2",
        "--threads",
        "1",
    ]))
    .unwrap();
    // Re-packing an already-packed file is a hard error, not silent
    // double-encoding.
    assert!(cli::run(&argv(&[
        "pack",
        "--data",
        sofc_path.to_str().unwrap(),
        "--out",
        tmp("soforest_e2e_repack.sofc").to_str().unwrap(),
    ]))
    .is_err());
    std::fs::remove_file(&sofc_path).ok();
}

#[test]
fn pack_bins_quantizes_and_trains_end_to_end() {
    // The quantized v2 surface: `pack --bins` from a generator spec and
    // from CSV, training on the binned file, and the v1 -> v2 re-pack.
    let csv_path = tmp("soforest_e2e_bins.csv");
    let v1_path = tmp("soforest_e2e_bins_v1.sofc");
    let v2_spec = tmp("soforest_e2e_bins_spec.sofc");
    let v2_csv = tmp("soforest_e2e_bins_csv.sofc");
    let v2_repack = tmp("soforest_e2e_bins_repack.sofc");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:400:6",
        "--seed",
        "7",
        "--out",
        csv_path.to_str().unwrap(),
    ]))
    .unwrap();
    // Generator spec -> v2.
    cli::run(&argv(&[
        "pack",
        "--data",
        "trunk:400:6",
        "--seed",
        "7",
        "--bins",
        "255",
        "--out",
        v2_spec.to_str().unwrap(),
    ]))
    .unwrap();
    // CSV -> v2 (streaming two-pass quantizing pack).
    cli::run(&argv(&[
        "pack",
        "--data",
        csv_path.to_str().unwrap(),
        "--bins",
        "64",
        "--out",
        v2_csv.to_str().unwrap(),
    ]))
    .unwrap();
    // v1 float file -> v2 (re-pack through the mapped backend).
    cli::run(&argv(&[
        "pack",
        "--data",
        csv_path.to_str().unwrap(),
        "--out",
        v1_path.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "pack",
        "--data",
        v1_path.to_str().unwrap(),
        "--bins",
        "64",
        "--out",
        v2_repack.to_str().unwrap(),
    ]))
    .unwrap();
    // All three binned files sniff as column files and train end-to-end.
    for p in [&v2_spec, &v2_csv, &v2_repack] {
        assert!(soforest::data::colfile::sniff(p));
        cli::run(&argv(&[
            "train",
            "--data",
            p.to_str().unwrap(),
            "--trees",
            "2",
            "--threads",
            "2",
        ]))
        .unwrap();
    }
    // Quantizing an already-binned file is a hard error, not silent
    // double-quantization.
    assert!(cli::run(&argv(&[
        "pack",
        "--data",
        v2_csv.to_str().unwrap(),
        "--bins",
        "32",
        "--out",
        tmp("soforest_e2e_bins_double.sofc").to_str().unwrap(),
    ]))
    .is_err());
    // Out-of-range bin counts are rejected up front.
    assert!(cli::run(&argv(&[
        "pack",
        "--data",
        csv_path.to_str().unwrap(),
        "--bins",
        "300",
        "--out",
        tmp("soforest_e2e_bins_bad.sofc").to_str().unwrap(),
    ]))
    .is_err());
    for p in [csv_path, v1_path, v2_spec, v2_csv, v2_repack] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn score_reads_packed_column_files() {
    // Satellite: `score` accepts .sofc input (v1 float and v2 binned)
    // through the blocked mapped-row scorer, with predictions written out.
    let (model, csv) = train_model("score_sofc");
    let v1 = tmp("soforest_e2e_score_v1.sofc");
    let v2 = tmp("soforest_e2e_score_v2.sofc");
    let preds = tmp("soforest_e2e_score_sofc_preds.csv");
    cli::run(&argv(&[
        "pack",
        "--data",
        csv.to_str().unwrap(),
        "--out",
        v1.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "pack",
        "--data",
        csv.to_str().unwrap(),
        "--bins",
        "64",
        "--out",
        v2.to_str().unwrap(),
    ]))
    .unwrap();
    for sofc in [&v1, &v2] {
        cli::run(&argv(&[
            "score",
            "--model",
            model.to_str().unwrap(),
            "--data",
            sofc.to_str().unwrap(),
            "--block",
            "64",
            "--threads",
            "2",
            "--out",
            preds.to_str().unwrap(),
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&preds).unwrap();
        assert_eq!(text.lines().count(), 301); // header + 300 predictions
    }
    for p in [model, csv, v1, v2, preds] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn gen_data_writes_sofc_shards() {
    // Satellite: `gen-data --shards N` emits N contiguous .sofc shards,
    // float or (--bins) quantized, each trainable on its own.
    let stem = tmp("soforest_e2e_shards.sofc");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:450:6",
        "--seed",
        "9",
        "--shards",
        "3",
        "--out",
        stem.to_str().unwrap(),
    ]))
    .unwrap();
    let base = stem.to_str().unwrap().strip_suffix(".sofc").unwrap();
    let mut total = 0usize;
    for i in 0..3 {
        let shard = PathBuf::from(format!("{base}.shard{i}.sofc"));
        assert!(soforest::data::colfile::sniff(&shard), "shard {i} missing");
        let d = soforest::data::colfile::load_mapped(&shard).unwrap();
        total += d.n_samples();
        cli::run(&argv(&[
            "train",
            "--data",
            shard.to_str().unwrap(),
            "--trees",
            "1",
            "--threads",
            "1",
        ]))
        .unwrap();
        std::fs::remove_file(&shard).ok();
    }
    assert_eq!(total, 450, "shards must partition the table");
    // Quantized shards.
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:300:6",
        "--shards",
        "2",
        "--bins",
        "32",
        "--out",
        stem.to_str().unwrap(),
    ]))
    .unwrap();
    for i in 0..2 {
        let shard = PathBuf::from(format!("{base}.shard{i}.sofc"));
        let d = soforest::data::colfile::load_mapped(&shard).unwrap();
        assert_eq!(d.backend_name(), "mmap-binned", "shard {i}");
        std::fs::remove_file(&shard).ok();
    }
    // More shards than rows is a hard error.
    assert!(cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:5:4",
        "--shards",
        "9",
        "--out",
        stem.to_str().unwrap(),
    ]))
    .is_err());
}

#[test]
fn eval_reports_quantization_delta() {
    // Satellite: the quantized-training leg is opt-in and reports its
    // accuracy delta vs float training (checked here to run end-to-end;
    // the printed delta line is asserted by the CI pack e2e step).
    cli::run(&argv(&[
        "eval",
        "--data",
        "trunk:500:8",
        "--trees",
        "4",
        "--threads",
        "1",
        "--test-frac",
        "0.3",
        "--quantize",
        "32",
    ]))
    .unwrap();
    // Pre-binned input has no float baseline to compare against.
    let v2 = tmp("soforest_e2e_eval_binned.sofc");
    cli::run(&argv(&[
        "pack",
        "--data",
        "trunk:300:6",
        "--bins",
        "32",
        "--out",
        v2.to_str().unwrap(),
    ]))
    .unwrap();
    assert!(cli::run(&argv(&[
        "eval",
        "--data",
        v2.to_str().unwrap(),
        "--trees",
        "2",
        "--quantize",
        "32",
    ]))
    .is_err());
    std::fs::remove_file(&v2).ok();
}

#[test]
fn corrupt_column_files_are_rejected() {
    let sofc_path = tmp("soforest_e2e_pack_corrupt.sofc");
    cli::run(&argv(&[
        "pack",
        "--data",
        "trunk:200:5",
        "--out",
        sofc_path.to_str().unwrap(),
    ]))
    .unwrap();
    let pristine = std::fs::read(&sofc_path).unwrap();

    // Truncated: cut the file mid-column-section.
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(&sofc_path)
        .unwrap();
    f.set_len(pristine.len() as u64 / 2).unwrap();
    drop(f);
    let err = cli::run(&argv(&[
        "train",
        "--data",
        sofc_path.to_str().unwrap(),
        "--trees",
        "1",
    ]))
    .unwrap_err()
    .to_string();
    assert!(err.contains("truncated"), "{err}");

    // Bad magic: the file no longer sniffs as a column file and the CSV
    // fallback rejects the binary junk — either way, a hard error.
    let mut bad = pristine.clone();
    bad[0] ^= 0xFF;
    std::fs::write(&sofc_path, &bad).unwrap();
    assert!(cli::run(&argv(&[
        "train",
        "--data",
        sofc_path.to_str().unwrap(),
        "--trees",
        "1",
    ]))
    .is_err());

    // Wrong endianness: byte-swapped mark (a file packed on an
    // opposite-endian host) must be refused with a pointed message.
    let mut swapped = pristine;
    swapped[8..12].reverse();
    std::fs::write(&sofc_path, &swapped).unwrap();
    let err = cli::run(&argv(&[
        "train",
        "--data",
        sofc_path.to_str().unwrap(),
        "--trees",
        "1",
    ]))
    .unwrap_err()
    .to_string();
    assert!(err.contains("endianness"), "{err}");
    std::fs::remove_file(&sofc_path).ok();
}
