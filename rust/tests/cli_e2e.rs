//! End-to-end CLI tests: drive `soforest::cli::run` exactly as the binary
//! does, including CSV round-trips through the filesystem.

use soforest::cli;
use std::path::PathBuf;

fn argv(s: &[&str]) -> Vec<String> {
    s.iter().map(|x| x.to_string()).collect()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

#[test]
fn train_on_generated_data() {
    cli::run(&argv(&[
        "train",
        "--data",
        "susy:300",
        "--trees",
        "3",
        "--threads",
        "1",
        "--seed",
        "5",
    ]))
    .unwrap();
}

#[test]
fn train_with_instrumentation_and_dynamic_strategy() {
    cli::run(&argv(&[
        "train",
        "--data",
        "trunk:400:16",
        "--trees",
        "2",
        "--threads",
        "1",
        "--strategy",
        "dynamic",
        "--instrument",
        "--sort_below",
        "128",
    ]))
    .unwrap();
}

#[test]
fn eval_reports_holdout_and_rf_baseline() {
    cli::run(&argv(&[
        "eval",
        "--data",
        "trunk:500:8",
        "--trees",
        "5",
        "--threads",
        "1",
        "--test-frac",
        "0.3",
    ]))
    .unwrap();
}

#[test]
fn gen_data_then_train_from_csv() {
    let path = tmp("soforest_e2e_data.csv");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "credit-approval:200",
        "--out",
        path.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        path.to_str().unwrap(),
        "--trees",
        "2",
        "--threads",
        "1",
    ]))
    .unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn might_protocol_runs() {
    cli::run(&argv(&[
        "might",
        "--data",
        "trunk:400:8",
        "--trees",
        "8",
        "--threads",
        "1",
        "--replicates",
        "2",
    ]))
    .unwrap();
}

#[test]
fn config_file_plus_flag_overrides() {
    let cfg_path = tmp("soforest_e2e_cfg.toml");
    std::fs::write(&cfg_path, "n_trees = 2\nstrategy = \"exact\"\nthreads = 1\n").unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        "trunk:200:8",
        "--config",
        cfg_path.to_str().unwrap(),
        "--strategy",
        "histogram", // flag wins over file
    ]))
    .unwrap();
    std::fs::remove_file(cfg_path).ok();
}

#[test]
fn unknown_command_and_flags_error() {
    assert!(cli::run(&argv(&["frobnicate"])).is_err());
    assert!(cli::run(&argv(&["train"])).is_err()); // missing --data
    assert!(cli::run(&argv(&["train", "--data", "nosuchgen:10"])).is_err());
}

#[test]
fn info_and_help_always_succeed() {
    cli::run(&argv(&["help"])).unwrap();
    cli::run(&argv(&["info", "--artifacts", "/nonexistent"])).unwrap();
}

#[test]
fn train_save_predict_roundtrip() {
    let model = tmp("soforest_e2e_model.bin");
    let csv = tmp("soforest_e2e_predict.csv");
    let preds = tmp("soforest_e2e_preds.csv");
    cli::run(&argv(&[
        "gen-data",
        "--data",
        "trunk:300:8",
        "--out",
        csv.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "train",
        "--data",
        csv.to_str().unwrap(),
        "--trees",
        "4",
        "--threads",
        "1",
        "--oob",
        "--out",
        model.to_str().unwrap(),
    ]))
    .unwrap();
    cli::run(&argv(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--data",
        csv.to_str().unwrap(),
        "--out",
        preds.to_str().unwrap(),
    ]))
    .unwrap();
    let text = std::fs::read_to_string(&preds).unwrap();
    assert_eq!(text.lines().count(), 301); // header + 300 predictions
    // Mismatched feature count must error.
    assert!(cli::run(&argv(&[
        "predict",
        "--model",
        model.to_str().unwrap(),
        "--data",
        "trunk:50:16",
    ]))
    .is_err());
    for p in [model, csv, preds] {
        std::fs::remove_file(p).ok();
    }
}

#[test]
fn importance_command_runs() {
    cli::run(&argv(&[
        "importance",
        "--data",
        "sparse-parity:300:8",
        "--trees",
        "8",
        "--threads",
        "1",
        "--repeats",
        "2",
        "--top",
        "4",
    ]))
    .unwrap();
}
