//! Sharded-training equivalence (in the style of `frontier_equivalence.rs`):
//! training on a [`ShardedColumns`] store — per-shard partial histogram
//! fills merged in fixed shard-index order — must produce **byte-identical**
//! forests (same v2 serialization) to training on the concatenated
//! single-store table, at any shard count × thread count × engine flag
//! (`fused`, `hist_subtraction`, `simd`). Plus an engagement guard (the
//! shard tier must actually run, not pass vacuously) and a file-backed leg
//! through stamped `.sofc` members.

use soforest::config::ForestConfig;
use soforest::coordinator::{train_forest, train_forest_with_source};
use soforest::data::shards::{from_parts, load_sharded};
use soforest::data::synth::trunk::TrunkConfig;
use soforest::data::Dataset;
use soforest::forest::serialize::write_packed;
use soforest::forest::tree::ProjectionSource;
use soforest::forest::{Forest, PackedForest};
use soforest::rng::Pcg64;

fn trunk(n: usize, d: usize, seed: u64) -> Dataset {
    TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(seed))
}

/// Split a table into `k` row-range members (the layout `gen-data
/// --shards k` produces) and compose them into a sharded store.
fn shard(data: &Dataset, k: usize) -> Dataset {
    let n = data.n_samples();
    let parts: Vec<Dataset> = (0..k)
        .map(|i| {
            let ids: Vec<u32> = (i * n / k..(i + 1) * n / k).map(|r| r as u32).collect();
            data.subset(&ids)
        })
        .collect();
    from_parts(parts).expect("valid shard set")
}

/// Canonical v2 bytes of a forest (the serving format the acceptance bar
/// is stated in).
fn v2_bytes(forest: &Forest) -> Vec<u8> {
    let packed = PackedForest::from_forest(forest).expect("packable forest");
    let mut bytes = Vec::new();
    write_packed(&packed, &mut bytes).expect("in-memory serialization");
    bytes
}

/// A config whose histogram tier (and therefore the shard tier) is
/// reachable on a few-thousand-row table: small bins, low sort crossover.
fn shard_cfg(threads: usize) -> ForestConfig {
    let mut cfg = ForestConfig {
        n_trees: 2,
        n_threads: threads,
        n_bins: 32,
        ..Default::default()
    };
    cfg.thresholds.sort_below = 64;
    cfg
}

#[test]
fn sharded_forests_match_single_store_bytes_across_shards_and_threads() {
    let data = trunk(2400, 10, 0x5A);
    let reference = v2_bytes(&train_forest(&data, &shard_cfg(1), 0xCAFE));
    for shards in [1usize, 2, 4] {
        let sharded = shard(&data, shards);
        assert_eq!(sharded.n_shards(), if shards == 1 { 1 } else { shards });
        for threads in [1usize, 2, 8] {
            let bytes = v2_bytes(&train_forest(&sharded, &shard_cfg(threads), 0xCAFE));
            assert_eq!(
                reference, bytes,
                "forest bytes differ for {shards} shards at {threads} threads"
            );
        }
    }
}

#[test]
fn sharded_forests_match_across_engine_flags() {
    // The shard tier always fills through the fused/binned/SIMD fill
    // paths; the single-store run flips every engine flag. Byte-identity
    // across the full cross-product pins the shard pipeline to BOTH
    // fresh-search engines' RNG and arithmetic contracts.
    let data = trunk(2000, 8, 0x5B);
    let train_with = |data: &Dataset, fused: bool, sub: bool, simd: bool| {
        let mut cfg = shard_cfg(2);
        cfg.fused = fused;
        cfg.hist_subtraction = sub;
        cfg.simd = simd;
        v2_bytes(&train_forest(data, &cfg, 0xD0D))
    };
    let reference = train_with(&data, true, true, true);
    let sharded = shard(&data, 3);
    for fused in [true, false] {
        for sub in [true, false] {
            for simd in [true, false] {
                assert_eq!(
                    reference,
                    train_with(&sharded, fused, sub, simd),
                    "sharded forest bytes differ for fused={fused} \
                     hist_subtraction={sub} simd={simd}"
                );
            }
        }
    }
}

#[test]
fn shard_tier_engages_on_this_workload() {
    // Guard against the equivalence tests passing vacuously: the same
    // workload must actually route nodes through the per-shard fill +
    // merge pipeline (visible as shard_fills in the per-level stats).
    let data = trunk(2400, 10, 0x5A);
    let sharded = shard(&data, 4);
    let mut cfg = shard_cfg(2);
    cfg.n_trees = 1;
    cfg.instrument = true;
    let out = train_forest_with_source(&sharded, &cfg, 0xCAFE, ProjectionSource::SparseOblique);
    let fills: u64 = out.stats.by_level.iter().map(|l| l.shard_fills).sum();
    assert!(
        fills > 0,
        "no node ever took the per-shard fill + merge path"
    );
    // Partial fills outnumber shard-tier merges only if nodes really
    // fan out over > 1 shard; require at least one 2+-shard node.
    let tails: u64 = out.stats.by_level.iter().map(|l| l.tail_nodes).sum();
    assert!(tails > 0, "tail completion never engaged on sharded data");
}

#[test]
fn quantized_shards_match_single_store_bytes() {
    // Binned members share one global layout (what `gen-data --shards`
    // guarantees by quantizing before splitting); the direct bin-id fill
    // path must survive the per-shard fan-out bit-for-bit.
    let data = trunk(2000, 8, 0x5C).quantized(32);
    let reference = v2_bytes(&train_forest(&data, &shard_cfg(2), 0xB1));
    let sharded = shard(&data, 3);
    assert_eq!(sharded.backend_name(), "sharded-binned");
    let bytes = v2_bytes(&train_forest(&sharded, &shard_cfg(2), 0xB1));
    assert_eq!(reference, bytes, "binned sharded forest bytes differ");
}

#[test]
fn file_backed_shards_match_in_memory_training() {
    // End-to-end through the on-disk format: write stamped members,
    // reload through the manifest loader, train, compare bytes against
    // the in-memory concatenated table.
    use soforest::data::colfile::{append_shard_stamp, write_dataset, ShardStamp};
    let data = trunk(1200, 6, 0x5D);
    let dir = std::env::temp_dir().join(format!("soforest_shard_eq_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let n = data.n_samples();
    let k = 3usize;
    let mut paths = Vec::new();
    let mut at = 0u64;
    for i in 0..k {
        let ids: Vec<u32> = (i * n / k..(i + 1) * n / k).map(|r| r as u32).collect();
        let part = data.subset(&ids);
        let path = dir.join(format!("t.shard{i}.sofc"));
        write_dataset(&part, &path).unwrap();
        append_shard_stamp(
            &path,
            ShardStamp {
                row_offset: at,
                total_rows: n as u64,
            },
        )
        .unwrap();
        at += part.n_samples() as u64;
        paths.push(path);
    }
    let sharded = load_sharded(&paths).unwrap();
    assert_eq!(sharded.n_shards(), k);
    let reference = v2_bytes(&train_forest(&data, &shard_cfg(2), 0x11F));
    let bytes = v2_bytes(&train_forest(&sharded, &shard_cfg(2), 0x11F));
    assert_eq!(reference, bytes, "file-backed sharded forest bytes differ");
    std::fs::remove_dir_all(&dir).ok();
}
