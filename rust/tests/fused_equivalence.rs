//! Property tests: the fused gather→route→accumulate pipeline must be
//! *bit-identical* to the classic materialize-then-route path — same bin
//! counts, same boundaries, same chosen (projection, threshold, gain), and
//! the same RNG state left behind — across layouts (64 / 256 bins), 2–5
//! classes, duplicate boundaries, and NaN values.

use soforest::data::Dataset;
use soforest::projection::apply::{apply_projection, gather_labels};
use soforest::projection::Projection;
use soforest::rng::Pcg64;
use soforest::split::histogram::{best_split_histogram, Routing};
use soforest::split::{best_split_fused, Split, SplitCriterion, SplitScratch};

struct Case {
    data: Dataset,
    projections: Vec<Projection>,
    active: Vec<u32>,
    labels: Vec<u16>,
    parent: Vec<usize>,
}

/// Random node workload. `discrete` draws column values from a 7-point grid
/// so boundary sampling produces heavy duplicates; `with_nan` poisons ~5%
/// of the first column with NaN.
fn random_case(rng: &mut Pcg64, n_classes: usize, discrete: bool, with_nan: bool) -> Case {
    let d = 4 + rng.index(8);
    let n = n_classes * 2 + 50 + rng.index(2500);
    let columns: Vec<Vec<f32>> = (0..d)
        .map(|f| {
            (0..n)
                .map(|i| {
                    if with_nan && f == 0 && rng.bernoulli(0.05) {
                        f32::NAN
                    } else if discrete {
                        rng.index(7) as f32 * 0.5 - 1.5
                    } else {
                        rng.normal() as f32 + (i % n_classes) as f32 * 0.3
                    }
                })
                .collect()
        })
        .collect();
    let raw_labels: Vec<u16> = (0..n).map(|i| (i % n_classes) as u16).collect();
    let data = Dataset::from_columns(columns, raw_labels);
    let mut projections: Vec<Projection> = (0..5)
        .map(|_| {
            let k = 1 + rng.index(3);
            let terms = (0..k).map(|_| (rng.index(d) as u32, rng.sign())).collect();
            Projection { terms }
        })
        .collect();
    // An empty projection: both paths must skip it without touching the RNG.
    projections.insert(rng.index(projections.len() + 1), Projection::default());
    let active: Vec<u32> = (0..n as u32).filter(|i| i % 4 != 1).collect();
    let mut labels = Vec::new();
    gather_labels(&data, &active, &mut labels);
    let mut parent = vec![0usize; n_classes];
    for &l in &labels {
        parent[l as usize] += 1;
    }
    Case {
        data,
        projections,
        active,
        labels,
        parent,
    }
}

/// Classic per-projection loop, as `TreeTrainer::split_node` runs it with
/// `fused = off`. Also returns, for every splittable projection, the
/// (boundaries, counts) the histogram engine produced.
#[allow(clippy::type_complexity)]
fn classic_reference(
    case: &Case,
    n_bins: usize,
    routing: Routing,
    rng: &mut Pcg64,
) -> (Option<(usize, Split)>, Vec<Option<(Vec<f32>, Vec<u32>)>>) {
    let mut scratch = SplitScratch::default();
    let mut values = Vec::new();
    let mut best: Option<(usize, Split)> = None;
    let mut tables: Vec<Option<(Vec<f32>, Vec<u32>)>> = Vec::new();
    for (pi, proj) in case.projections.iter().enumerate() {
        if proj.is_empty() {
            tables.push(None);
            continue;
        }
        apply_projection(&case.data, proj, &case.active, &mut values);
        let split = best_split_histogram(
            &values,
            &case.labels,
            &case.parent,
            SplitCriterion::Entropy,
            n_bins,
            1,
            rng,
            &mut scratch,
            routing,
        );
        // best_split_histogram leaves boundaries/counts for the *last*
        // filled projection in scratch; snapshot them. When the projection
        // is constant, build_boundaries bails before pushing the +∞ pad, so
        // "did it fill" is observable from the boundary-buffer length.
        let filled = scratch.boundaries.len() == n_bins;
        if filled {
            tables.push(Some((scratch.boundaries.clone(), scratch.counts.clone())));
        } else {
            tables.push(None);
        }
        if let Some(s) = split {
            if best.as_ref().map_or(true, |(_, b)| s.gain > b.gain) {
                best = Some((pi, s));
            }
        }
    }
    (best, tables)
}

fn check_case(seed: u64, n_classes: usize, n_bins: usize, routing: Routing, discrete: bool, with_nan: bool) {
    let mut gen = Pcg64::new(seed);
    let case = random_case(&mut gen, n_classes, discrete, with_nan);

    let mut rng_classic = Pcg64::new(seed ^ 0xDECADE);
    let mut rng_fused = Pcg64::new(seed ^ 0xDECADE);
    let (classic_best, tables) = classic_reference(&case, n_bins, routing, &mut rng_classic);

    let mut scratch = SplitScratch::default();
    let fused_best = best_split_fused(
        &case.data,
        &case.projections,
        &case.active,
        &case.labels,
        &case.parent,
        SplitCriterion::Entropy,
        n_bins,
        1,
        routing,
        &mut rng_fused,
        &mut scratch,
    );

    let ctx = format!(
        "seed {seed} classes {n_classes} bins {n_bins} routing {routing:?} \
         discrete {discrete} nan {with_nan}"
    );

    // 1. Winner identical (bit-level threshold/gain).
    match (classic_best, fused_best) {
        (None, None) => {}
        (Some((cpi, cs)), Some((fpi, fs))) => {
            assert_eq!(cpi, fpi, "{ctx}: winning projection differs");
            assert_eq!(
                cs.threshold.to_bits(),
                fs.threshold.to_bits(),
                "{ctx}: threshold differs"
            );
            assert_eq!(cs.gain.to_bits(), fs.gain.to_bits(), "{ctx}: gain differs");
            assert_eq!(cs.n_left, fs.n_left, "{ctx}");
            assert_eq!(cs.n_right, fs.n_right, "{ctx}");
        }
        (c, f) => panic!("{ctx}: classic {c:?} vs fused {f:?}"),
    }

    // 2. Bit-identical per-projection histogram state.
    let stride = n_bins * n_classes;
    for (pi, table) in tables.iter().enumerate() {
        match table {
            None => assert!(
                !scratch.fused_ok[pi],
                "{ctx}: projection {pi} splittable only in fused path"
            ),
            Some((bounds, counts)) => {
                assert!(scratch.fused_ok[pi], "{ctx}: projection {pi} dropped by fused");
                let fb = &scratch.fused_boundaries[pi * n_bins..(pi + 1) * n_bins];
                let fc = &scratch.fused_counts[pi * stride..(pi + 1) * stride];
                let same_bounds = bounds
                    .iter()
                    .zip(fb)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same_bounds, "{ctx}: boundaries differ for projection {pi}");
                assert_eq!(counts.as_slice(), fc, "{ctx}: bin counts differ for projection {pi}");
            }
        }
    }

    // 3. Both paths consumed the RNG identically.
    assert_eq!(
        rng_classic.next_u64(),
        rng_fused.next_u64(),
        "{ctx}: RNG state diverged"
    );
}

#[test]
fn fused_equals_classic_two_level_256() {
    let mut meta = Pcg64::new(0x256256);
    for _ in 0..12 {
        let seed = meta.next_u64();
        let n_classes = 2 + (seed % 4) as usize;
        check_case(seed, n_classes, 256, Routing::TwoLevel, false, false);
    }
}

#[test]
fn fused_equals_classic_two_level_64() {
    let mut meta = Pcg64::new(0x646464);
    for _ in 0..12 {
        let seed = meta.next_u64();
        let n_classes = 2 + (seed % 4) as usize;
        check_case(seed, n_classes, 64, Routing::TwoLevel, false, false);
    }
}

#[test]
fn fused_equals_classic_binary_search_routing() {
    let mut meta = Pcg64::new(0xB15EC);
    for _ in 0..8 {
        let seed = meta.next_u64();
        check_case(seed, 2 + (seed % 2) as usize, 256, Routing::BinarySearch, false, false);
    }
}

#[test]
fn fused_equals_classic_with_duplicate_boundaries() {
    let mut meta = Pcg64::new(0xD0B1E5);
    for _ in 0..10 {
        let seed = meta.next_u64();
        let n_classes = 2 + (seed % 4) as usize;
        check_case(seed, n_classes, 256, Routing::TwoLevel, true, false);
        check_case(seed ^ 1, n_classes, 64, Routing::TwoLevel, true, false);
    }
}

#[test]
fn fused_equals_classic_with_nan_values() {
    let mut meta = Pcg64::new(0x7A9A0);
    for _ in 0..10 {
        let seed = meta.next_u64();
        let n_classes = 2 + (seed % 4) as usize;
        check_case(seed, n_classes, 256, Routing::TwoLevel, false, true);
        check_case(seed ^ 3, n_classes, 64, Routing::TwoLevel, true, true);
    }
}
