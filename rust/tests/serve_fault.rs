//! Fault-injection integration suite for the serve tier (requires the
//! `serve-fault` feature: `cargo test --features serve-fault --test serve_fault`).
//!
//! Each test stands up a real TCP server with an injected fault plan
//! ([`soforest::serve::fault`]) and asserts the robustness contract from
//! the other side of the socket:
//!
//! * faults are **shed explicitly** (`!err`, `!timeout`, a dropped
//!   connection) — never a wedged worker or a silent wrong answer,
//! * the server **recovers**: connections after a fault are served
//!   normally,
//! * the drained aggregate [`ServeStats`] equals what the clients
//!   observed — a panicking handler loses its own connection only,
//! * shutdown always completes promptly (the join-time bound in
//!   `with_server` is the no-deadlock assertion for every test).
//!
//! Faults are counter-based ("every k-th batch / connection") and all
//! clients here run serially, so which connection is hit is deterministic
//! regardless of worker scheduling.

use soforest::config::ForestConfig;
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::PackedForest;
use soforest::rng::Pcg64;
use soforest::serve::fault::{FaultPlan, FaultState};
use soforest::serve::{serve_tcp, ServeConfig, ServeStats, Shutdown};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Small forest plus one valid request line for it.
fn fixture() -> (PackedForest, String) {
    let data = TrunkConfig {
        n_samples: 400,
        n_features: 8,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(21));
    let cfg = ForestConfig {
        n_trees: 10,
        n_threads: 1,
        ..Default::default()
    };
    let forest = train_forest(&data, &cfg, 4);
    let packed = PackedForest::from_forest(&forest).unwrap();
    let mut row = Vec::new();
    data.row(0, &mut row);
    let line = row
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(",");
    (packed, line)
}

/// Run `serve_tcp` for the duration of `client`, then stop, join, and
/// return the drained stats. The bounded join time doubles as the
/// no-deadlock/no-wedge assertion of every test that goes through here.
fn with_server(
    packed: &PackedForest,
    cfg: &ServeConfig,
    shutdown: &Shutdown,
    pf_name: &str,
    client: impl FnOnce(&Path),
) -> ServeStats {
    let pf = std::env::temp_dir().join(pf_name);
    std::fs::remove_file(&pf).ok();
    let cfg = cfg.clone().with_port_file(&pf);
    let stats = std::thread::scope(|scope| {
        let server = scope.spawn(|| serve_tcp(packed, &cfg, shutdown).unwrap());
        client(&pf);
        shutdown.request_stop();
        let t0 = Instant::now();
        let stats = server.join().expect("server thread");
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "shutdown wedged: drain took {:?}",
            t0.elapsed()
        );
        stats
    });
    std::fs::remove_file(&pf).ok();
    stats
}

/// Wait for the port file, then connect.
fn connect(pf: &Path) -> TcpStream {
    for _ in 0..2000 {
        if let Ok(s) = std::fs::read_to_string(pf) {
            if !s.is_empty() {
                return TcpStream::connect(s.trim()).unwrap();
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("server never wrote the port file");
}

/// One request, one connection: send `line`, return the first response
/// line — `None` when the server dropped the connection unanswered
/// (injected disconnects may surface client-side as a reset, not EOF).
fn one_shot(pf: &Path, line: &str) -> Option<String> {
    let mut conn = connect(pf);
    conn.write_all(line.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    conn.shutdown(std::net::Shutdown::Write).unwrap();
    let mut reader = BufReader::new(conn);
    let mut resp = String::new();
    match reader.read_line(&mut resp) {
        Ok(0) | Err(_) => None,
        Ok(_) => Some(resp.trim_end().to_string()),
    }
}

#[test]
fn injected_panics_cost_only_their_connection() {
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            panic_every_batch: Some(2),
            ..Default::default()
        }))),
        ..Default::default()
    };
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_panic_port", |pf| {
        // Serial one-line connections: connection k is batch k, so the
        // even ones panic (dropped unanswered) and the odd ones answer.
        let answers: Vec<Option<String>> = (0..4).map(|_| one_shot(pf, &line)).collect();
        for (k, a) in answers.iter().enumerate() {
            if (k + 1) % 2 == 0 {
                assert!(a.is_none(), "conn {} survived its injected panic: {a:?}", k + 1);
            } else {
                let a = a.as_deref().unwrap_or_else(|| panic!("conn {} unanswered", k + 1));
                assert!(a.parse::<u16>().is_ok(), "conn {}: {a}", k + 1);
            }
        }
    });
    // The aggregate survived both panics: the two answered requests are
    // counted, the two doomed connections cost exactly themselves.
    assert_eq!(stats.panics, 2);
    assert_eq!(stats.conns, 4);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.errors, 0);
}

#[test]
fn injected_stalls_turn_into_explicit_timeouts() {
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        // Every batch stalls 30-90 ms before scoring; the 10 ms deadline
        // has always passed by then, so every request must be answered
        // with an explicit `!timeout <seq>` — never a late prediction.
        deadline: Duration::from_millis(10),
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            seed: 7,
            stall_every_batch: Some(1),
            stall: Duration::from_millis(60),
            ..Default::default()
        }))),
        ..Default::default()
    };
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_stall_port", |pf| {
        let mut conn = connect(pf);
        conn.write_all(format!("{line}\n{line}\n").as_bytes()).unwrap();
        conn.shutdown(std::net::Shutdown::Write).unwrap();
        let lines: Vec<String> = BufReader::new(conn).lines().map(|l| l.unwrap()).collect();
        // 1:1 correspondence holds under timeouts, and the seq numbers
        // tell the client which request each line answers.
        assert_eq!(lines, vec!["!timeout 1", "!timeout 2"], "{lines:?}");
    });
    assert_eq!(stats.timeouts, 2);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.errors, 0);
}

#[test]
fn mid_line_disconnects_are_contained() {
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            kill_conn_every: Some(2),
            ..Default::default()
        }))),
        ..Default::default()
    };
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_kill_port", |pf| {
        // The wire "cuts" one byte into every 2nd connection: those get
        // no answer; the server recovers and serves the next one.
        for k in 1..=4u64 {
            let a = one_shot(pf, &line);
            if k % 2 == 0 {
                assert!(a.is_none(), "killed conn {k} got an answer: {a:?}");
            } else {
                let a = a.unwrap_or_else(|| panic!("conn {k} unanswered"));
                assert!(a.parse::<u16>().is_ok(), "conn {k}: {a}");
            }
        }
    });
    assert_eq!(stats.conns, 4);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.panics, 0, "a disconnect is not a panic");
    assert_eq!(stats.disconnects, 2, "each cut wire counts once");
}

#[test]
fn injected_oversize_lines_answer_err_and_close() {
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        max_line_bytes: 256,
        // Every 2nd connection reads a synthetic 1 KiB unterminated line
        // before any real bytes — four times the cap.
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            oversize_conn_every: Some(2),
            oversize_len: 1024,
            ..Default::default()
        }))),
        ..Default::default()
    };
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_oversize_port", |pf| {
        // Conn 1 is clean and answered.
        let a = one_shot(pf, &line).expect("clean conn unanswered");
        assert!(a.parse::<u16>().is_ok(), "{a}");
        // Conn 2 sends nothing itself; the injected oversize prefix must
        // be refused with a bounded buffer, one `!err`, and a close.
        let conn = connect(pf);
        conn.shutdown(std::net::Shutdown::Write).unwrap();
        let mut reader = BufReader::new(conn);
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert_eq!(resp.trim_end(), "!err line exceeds 256 bytes");
        let mut rest = String::new();
        reader.read_to_string(&mut rest).ok();
        assert!(rest.is_empty(), "connection must close after the cap: {rest:?}");
    });
    assert_eq!(stats.conns, 2);
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.oversized, 1);
}

#[test]
fn fault_storm_preserves_aggregate_stats() {
    // Everything at once — disconnects, a panic, a stall — over 12 serial
    // connections. The drained aggregate must match exactly what the
    // clients observed, connection by connection.
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            seed: 3,
            kill_conn_every: Some(3),
            panic_every_batch: Some(5),
            stall_every_batch: Some(7),
            stall: Duration::from_millis(5),
            ..Default::default()
        }))),
        ..Default::default()
    };
    let mut answered = 0usize;
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_storm_port", |pf| {
        // Conns 3, 6, 9, 12 are killed (no batch). The survivors produce
        // batches 1..=8 in connection order, so conn 7 = batch 5 panics
        // and conn 10 = batch 7 stalls (harmless under the 1 s deadline).
        for k in 1..=12u64 {
            if let Some(a) = one_shot(pf, &line) {
                assert!(a.parse::<u16>().is_ok(), "conn {k}: {a}");
                answered += 1;
            } else {
                assert!(
                    k % 3 == 0 || k == 7,
                    "conn {k} dropped without an injected fault"
                );
            }
        }
        assert_eq!(answered, 7);
    });
    assert_eq!(stats.conns, 12);
    assert_eq!(stats.requests, answered, "aggregate != client observations");
    assert_eq!(stats.panics, 1);
    assert_eq!(stats.timeouts, 0);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.disconnects, 4, "one per killed connection");
}

#[test]
fn stats_line_is_an_exact_oracle_under_faults() {
    // The live `!stats` snapshot — not just the drained aggregate — must
    // exactly match client observations even while faults fire. Same storm
    // plan as above; after the 12 serial connections, a 13th connection
    // polls `!stats` and cross-checks every counter.
    let (packed, line) = fixture();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        max_wait: Duration::from_millis(1),
        fault: Some(Arc::new(FaultState::new(FaultPlan {
            seed: 3,
            kill_conn_every: Some(3),
            panic_every_batch: Some(5),
            stall_every_batch: Some(7),
            stall: Duration::from_millis(5),
            ..Default::default()
        }))),
        ..Default::default()
    };
    let stats = with_server(&packed, &cfg, &shutdown, "soforest_fault_oracle_port", |pf| {
        let mut answered = 0usize;
        for k in 1..=12u64 {
            if let Some(a) = one_shot(pf, &line) {
                assert!(a.parse::<u16>().is_ok(), "conn {k}: {a}");
                answered += 1;
            } else {
                assert!(k % 3 == 0 || k == 7, "conn {k} dropped unexpectedly");
            }
        }
        assert_eq!(answered, 7);
        // Poll the admin line. Every client-side event is already recorded
        // server-side by the time the client observed it (counters bump
        // before the response line is flushed, and a dropped connection is
        // only visible to the client after the server closed it), so the
        // snapshot is exact, not approximate. The poll connection is batch
        // #9 of the fault plan — it fires on_batch but trips nothing.
        let mut conn = connect(pf);
        conn.write_all(b"!stats\n").unwrap();
        let mut resp = String::new();
        BufReader::new(conn.try_clone().unwrap())
            .read_line(&mut resp)
            .unwrap();
        let snap = ServeStats::from_json_line(resp.trim()).expect("stats JSON");
        assert_eq!(snap.served, answered, "served != client-observed answers");
        assert_eq!(snap.requests, answered);
        assert_eq!(snap.conns, 13, "12 storm conns + this poll conn");
        assert_eq!(snap.panics, 1);
        assert_eq!(snap.disconnects, 4);
        assert_eq!(snap.errors, 0);
        assert_eq!(snap.timeouts, 0);
        assert_eq!(snap.shed, 0);
        assert_eq!(
            snap.latency.count as usize, answered,
            "one histogram sample per answered request"
        );
        conn.shutdown(std::net::Shutdown::Both).ok();
    });
    // The drained aggregate agrees with the live snapshot's view.
    assert_eq!(stats.conns, 13);
    assert_eq!(stats.requests, 7);
    assert_eq!(stats.panics, 1);
    assert_eq!(stats.disconnects, 4);
}
