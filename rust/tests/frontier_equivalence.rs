//! Frontier-scheduler equivalence properties (in the style of
//! `fused_equivalence.rs`): frontier growth must produce **byte-identical**
//! forests — same v2 serialization — for any thread count, across every
//! split strategy; and `--growth depth` must keep behaving exactly like the
//! pre-frontier trainer (its own thread-count invariance and purity
//! guarantees).

use soforest::config::{ForestConfig, GrowthMode};
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::data::Dataset;
use soforest::forest::serialize::write_packed;
use soforest::forest::{Forest, PackedForest};
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn trunk(n: usize, d: usize, seed: u64) -> Dataset {
    TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(seed))
}

/// Canonical v2 bytes of a forest (the serving format the acceptance bar
/// is stated in).
fn v2_bytes(forest: &Forest) -> Vec<u8> {
    let packed = PackedForest::from_forest(forest).expect("packable forest");
    let mut bytes = Vec::new();
    write_packed(&packed, &mut bytes).expect("in-memory serialization");
    bytes
}

const ALL_STRATEGIES: [SplitStrategy; 6] = [
    SplitStrategy::Exact,
    SplitStrategy::Histogram,
    SplitStrategy::VectorizedHistogram,
    SplitStrategy::Dynamic,
    SplitStrategy::DynamicVectorized,
    SplitStrategy::Hybrid,
];

#[test]
fn frontier_forests_are_byte_identical_across_thread_counts() {
    let data = trunk(500, 10, 0xF0);
    for strategy in ALL_STRATEGIES {
        let train_with = |threads: usize| {
            let mut cfg = ForestConfig {
                n_trees: 3,
                n_threads: threads,
                strategy,
                growth: GrowthMode::Frontier,
                ..Default::default()
            };
            // Exercise all three tiers: small nodes sort, mid nodes
            // histogram, large nodes classify to the accelerator tier (and
            // deterministically fall back — no device in the test env).
            cfg.thresholds.sort_below = 48;
            if strategy == SplitStrategy::Hybrid {
                cfg.thresholds.accel_above = 150;
            }
            v2_bytes(&train_forest(&data, &cfg, 0xBEEF))
        };
        let reference = train_with(1);
        for threads in [2, 8] {
            assert_eq!(
                reference,
                train_with(threads),
                "{strategy:?}: forest bytes differ between 1 and {threads} threads"
            );
        }
    }
}

#[test]
fn frontier_single_large_tree_is_thread_invariant() {
    // The single-tree case routes the entire thread budget into the
    // intra-tree frontier pool — the headline scaling scenario.
    let data = trunk(1500, 12, 0xF1);
    let train_with = |threads: usize| {
        let cfg = ForestConfig {
            n_trees: 1,
            n_threads: threads,
            growth: GrowthMode::Frontier,
            ..Default::default()
        };
        v2_bytes(&train_forest(&data, &cfg, 7))
    };
    let reference = train_with(1);
    for threads in [2, 8] {
        assert_eq!(reference, train_with(threads), "{threads} threads");
    }
}

#[test]
fn subtraction_on_off_forests_are_byte_identical_across_threads() {
    // Sibling-histogram subtraction must be a pure optimization: the v2
    // bytes are identical for `--hist_subtraction on|off` at any thread
    // count. Data is big enough that pairs actually form (root children
    // comfortably clear the n_bins floor over several levels), and
    // sort_below is lowered so mid-sized nodes reach the histogram tier.
    // Histogram (static) pins the Routing::BinarySearch inherited-fill
    // arm, VectorizedHistogram (static) the TwoLevel arm, and
    // DynamicVectorized the adaptive tiers + the cost-model upgrade of
    // the smaller pair half.
    let data = trunk(4000, 12, 0xF4);
    for strategy in [
        SplitStrategy::Histogram,
        SplitStrategy::VectorizedHistogram,
        SplitStrategy::DynamicVectorized,
    ] {
        let train_with = |sub: bool, threads: usize| {
            let mut cfg = ForestConfig {
                n_trees: 2,
                n_threads: threads,
                strategy,
                growth: GrowthMode::Frontier,
                hist_subtraction: sub,
                ..Default::default()
            };
            cfg.thresholds.sort_below = 512;
            v2_bytes(&train_forest(&data, &cfg, 0xAB))
        };
        let reference = train_with(true, 1);
        for threads in [1, 2, 8] {
            for sub in [true, false] {
                if sub && threads == 1 {
                    continue; // the reference itself
                }
                assert_eq!(
                    reference,
                    train_with(sub, threads),
                    "{strategy:?}: forest bytes differ for hist_subtraction={sub} \
                     at {threads} threads"
                );
            }
        }
    }
}

#[test]
fn simd_on_off_forests_are_byte_identical_across_threads_and_engines() {
    // The runtime-dispatched SIMD kernels must be pure optimizations: the
    // v2 bytes are identical with `--simd on` (the best ISA this CPU has)
    // and `--simd off` (forced scalar reference kernels), at any thread
    // count, on both the fused and the classic engine. The workload is
    // sized so the histogram tiers, sibling-subtraction pairs and the
    // fused block walk all engage — i.e. every dispatched kernel (route,
    // lower-bound fill, subtraction, projection gathers) actually runs.
    let data = trunk(3000, 12, 0xF5);
    let train_with = |simd: bool, fused: bool, threads: usize| {
        let mut cfg = ForestConfig {
            n_trees: 2,
            n_threads: threads,
            strategy: SplitStrategy::DynamicVectorized,
            growth: GrowthMode::Frontier,
            simd,
            fused,
            ..Default::default()
        };
        cfg.thresholds.sort_below = 256;
        v2_bytes(&train_forest(&data, &cfg, 0xD15))
    };
    let reference = train_with(true, true, 1);
    for threads in [1, 2, 8] {
        for simd in [true, false] {
            for fused in [true, false] {
                if simd && fused && threads == 1 {
                    continue; // the reference itself
                }
                assert_eq!(
                    reference,
                    train_with(simd, fused, threads),
                    "forest bytes differ for simd={simd} fused={fused} at {threads} threads"
                );
            }
        }
    }
}

#[test]
fn subtraction_engages_on_this_workload() {
    // Guard against the equivalence test above passing vacuously: the
    // same workload must actually route sibling pairs through the
    // subtraction path (visible in the per-level instrumentation).
    use soforest::coordinator::train_forest_with_source;
    use soforest::forest::tree::ProjectionSource;
    let data = trunk(4000, 12, 0xF4);
    let mut cfg = ForestConfig {
        n_trees: 1,
        n_threads: 1,
        strategy: SplitStrategy::DynamicVectorized,
        growth: GrowthMode::Frontier,
        instrument: true,
        ..Default::default()
    };
    cfg.thresholds.sort_below = 512;
    let out = train_forest_with_source(&data, &cfg, 0xAB, ProjectionSource::SparseOblique);
    let subs: u64 = out.stats.by_level.iter().map(|l| l.sub_nodes).sum();
    let fills: u64 = out.stats.by_level.iter().map(|l| l.inherit_fill_nodes).sum();
    assert!(subs > 0, "no node's tables were derived by subtraction");
    assert!(fills > 0, "no sibling direct-filled inherited tables");
    cfg.hist_subtraction = false;
    let off = train_forest_with_source(&data, &cfg, 0xAB, ProjectionSource::SparseOblique);
    let subs_off: u64 = off.stats.by_level.iter().map(|l| l.sub_nodes).sum();
    let fills_off: u64 = off.stats.by_level.iter().map(|l| l.inherit_fill_nodes).sum();
    assert_eq!(subs_off, 0, "subtraction counted with the flag off");
    assert!(
        fills_off > fills,
        "with subtraction off, both pair halves must direct-fill"
    );
}

#[test]
fn depth_growth_is_thread_invariant_too() {
    // The classic scheduler's (pre-existing) guarantee must survive the
    // refactor: per-tree RNG streams make it thread-invariant as well.
    let data = trunk(400, 8, 0xF2);
    for strategy in [SplitStrategy::Exact, SplitStrategy::DynamicVectorized] {
        let train_with = |threads: usize| {
            let cfg = ForestConfig {
                n_trees: 4,
                n_threads: threads,
                strategy,
                growth: GrowthMode::Depth,
                ..Default::default()
            };
            v2_bytes(&train_forest(&data, &cfg, 11))
        };
        assert_eq!(train_with(1), train_with(3), "{strategy:?}");
    }
}

#[test]
fn frontier_and_depth_forests_are_both_pure_and_accurate() {
    // The two schedulers draw different per-node RNG streams, so the trees
    // differ — but both must train to purity and classify their training
    // data perfectly (to-purity regime, min_leaf = 1).
    let data = trunk(600, 8, 0xF3);
    for growth in [GrowthMode::Depth, GrowthMode::Frontier] {
        let cfg = ForestConfig {
            n_trees: 5,
            n_threads: 2,
            bootstrap_fraction: 1.0,
            growth,
            ..Default::default()
        };
        let forest = train_forest(&data, &cfg, 3);
        let acc = forest.accuracy(&data);
        assert!(acc > 0.99, "{growth:?}: train accuracy {acc}");
    }
}
