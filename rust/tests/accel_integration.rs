//! End-to-end integration over the real AOT artifacts: the PJRT-compiled
//! node-split executable must agree with the rust CPU splitter on identical
//! inputs, and the hybrid strategy must train correct forests through it.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use soforest::accel::NodeSplitAccel;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::data::ActiveSet;
use soforest::forest::tree::{NodeAccel, ProjectionSource};
use soforest::rng::Pcg64;
use soforest::split::histogram::{build_boundaries, Routing};
use soforest::split::{self, SplitCriterion, SplitMethod, SplitScratch, SplitStrategy};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let dir: &'static Path = Box::leak(dir.into_boxed_path());
    if dir.join("model.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("[skip] no artifacts at {dir:?}; run `make artifacts`");
        None
    }
}

/// Build a node workload: values for `p` projections, labels, boundaries.
fn node_inputs(
    rng: &mut Pcg64,
    p: usize,
    n: usize,
    shift: f32,
) -> (Vec<f32>, Vec<u16>, Vec<f32>) {
    let labels: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
    let mut values = Vec::with_capacity(p * n);
    for pi in 0..p {
        let scale = 1.0 + pi as f32 * 0.3;
        for &l in labels.iter() {
            let v = rng.normal() as f32 * scale + if l == 1 { shift * scale } else { 0.0 };
            values.push(v);
        }
    }
    let mut boundaries = Vec::with_capacity(p * 256);
    let mut scratch = SplitScratch::default();
    for pi in 0..p {
        let vals = &values[pi * n..(pi + 1) * n];
        assert!(build_boundaries(vals, 256, rng, &mut scratch));
        boundaries.extend_from_slice(&scratch.boundaries);
    }
    (values, labels, boundaries)
}

#[test]
fn accel_loads_all_buckets() {
    let Some(dir) = artifacts_dir() else { return };
    let accel = NodeSplitAccel::try_load(dir).expect("load artifacts");
    assert!(!accel.buckets().is_empty());
    // Every advertised bucket must actually fit a workload of its own size.
    for b in accel.buckets().to_vec() {
        assert_eq!(accel.find_bucket(b.p, b.n), Some(b));
    }
    assert_eq!(accel.platform().to_lowercase().contains("cpu"), true);
}

#[test]
fn accel_agrees_with_cpu_splitter() {
    let Some(dir) = artifacts_dir() else { return };
    let mut accel = NodeSplitAccel::try_load(dir).unwrap();
    let mut rng = Pcg64::new(77);
    let (p, n) = (6, 3000);
    let (values, labels, boundaries) = node_inputs(&mut rng, p, n, 0.9);

    let (a_pi, a_edge, a_gain) = accel
        .execute_node(&values, p, n, &labels, &boundaries, 256)
        .expect("accel execute");

    // CPU: evaluate the same boundaries per projection with the scan used
    // by the histogram splitter.
    let parent = [n / 2 + n % 2, n / 2];
    let mut best: Option<(usize, usize, f64, f32)> = None;
    for pi in 0..p {
        let vals = &values[pi * n..(pi + 1) * n];
        let bounds = &boundaries[pi * 256..(pi + 1) * 256];
        let mut scratch = SplitScratch::default();
        scratch.boundaries = bounds.to_vec();
        soforest::split::vectorized::build_coarse(
            &scratch.boundaries,
            soforest::split::vectorized::TwoLevelLayout::for_bins(256).unwrap(),
            &mut scratch.coarse,
        );
        soforest::split::histogram::fill_histogram(
            vals,
            &labels,
            256,
            2,
            Routing::TwoLevel,
            &mut scratch,
        );
        if let Some(s) =
            soforest::split::histogram::best_edge(&parent, SplitCriterion::Entropy, 256, 1, &scratch)
        {
            if best.map_or(true, |(_, _, g, _)| s.gain > g) {
                // Recover the edge from the threshold.
                let edge = bounds.iter().position(|&b| b == s.threshold).unwrap();
                best = Some((pi, edge, s.gain, s.threshold));
            }
        }
    }
    let (c_pi, c_edge, c_gain, _) = best.expect("cpu found a split");

    assert_eq!(a_pi, c_pi, "winning projection differs");
    // f32 (accel) vs f64 (cpu) entropy: gains agree to ~1e-4, edges may
    // differ only between equal-gain ties.
    assert!(
        (a_gain - c_gain).abs() < 5e-4,
        "gain mismatch: accel {a_gain} vs cpu {c_gain}"
    );
    if a_edge != c_edge {
        let a_thr = boundaries[a_pi * 256 + a_edge];
        let c_thr = boundaries[c_pi * 256 + c_edge];
        assert!(
            (a_thr - c_thr).abs() < 1e-3,
            "edge differs beyond tie tolerance: {a_edge} vs {c_edge}"
        );
    }
}

#[test]
fn accel_padding_is_neutral() {
    // Same workload evaluated at n=3000 (padded to 4096) and n=4096 with
    // the tail zero-masked must produce the same winner.
    let Some(dir) = artifacts_dir() else { return };
    let mut accel = NodeSplitAccel::try_load(dir).unwrap();
    let mut rng = Pcg64::new(5);
    let (p, n) = (3, 2500);
    let (values, labels, boundaries) = node_inputs(&mut rng, p, n, 1.1);
    let (pi1, e1, g1) = accel
        .execute_node(&values, p, n, &labels, &boundaries, 256)
        .unwrap();
    let (pi2, e2, g2) = accel
        .execute_node(&values, p, n, &labels, &boundaries, 256)
        .unwrap();
    // Determinism of the whole path.
    assert_eq!((pi1, e1), (pi2, e2));
    assert_eq!(g1, g2);
    assert_eq!(accel.nodes_executed(), 2);
}

#[test]
fn accel_rejects_oversized_and_wrong_bins() {
    let Some(dir) = artifacts_dir() else { return };
    let mut accel = NodeSplitAccel::try_load(dir).unwrap();
    let max_n = accel.buckets().iter().map(|b| b.n).max().unwrap();
    let labels = vec![0u16; 8];
    let values = vec![0f32; 8];
    let boundaries = vec![f32::INFINITY; 256];
    assert!(accel
        .execute_node(&values, 1, 8, &labels, &boundaries, 64)
        .is_err());
    // Oversized n must be declined (trait returns None → CPU fallback).
    let big = max_n + 1;
    let r = accel.best_node_split(
        &vec![0f32; big],
        1,
        big,
        &vec![0u16; big],
        &boundaries,
        256,
        1,
    );
    assert!(r.is_none());
}

#[test]
fn hybrid_training_end_to_end_matches_cpu_accuracy() {
    let Some(dir) = artifacts_dir() else { return };
    let data = TrunkConfig {
        n_samples: 4000,
        n_features: 16,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(9));
    let mk_cfg = |strategy| {
        let mut cfg = ForestConfig {
            n_trees: 5,
            n_threads: 1,
            strategy,
            artifacts_dir: dir.to_string_lossy().into_owned(),
            ..Default::default()
        };
        cfg.thresholds.sort_below = 256;
        cfg.thresholds.accel_above = 1500;
        cfg
    };
    let hybrid = train_forest_with_source(
        &data,
        &mk_cfg(SplitStrategy::Hybrid),
        3,
        ProjectionSource::SparseOblique,
    );
    assert!(
        hybrid.accel_nodes > 0,
        "hybrid run never touched the accelerator"
    );
    let cpu = train_forest_with_source(
        &data,
        &mk_cfg(SplitStrategy::DynamicVectorized),
        3,
        ProjectionSource::SparseOblique,
    );
    let acc_h = hybrid.forest.accuracy(&data);
    let acc_c = cpu.forest.accuracy(&data);
    assert!(acc_h > 0.95, "hybrid accuracy {acc_h}");
    assert!(
        (acc_h - acc_c).abs() < 0.03,
        "hybrid {acc_h} vs cpu {acc_c} diverge"
    );
}

#[test]
fn cpu_splitters_cross_validate_on_projected_features() {
    // Pure-CPU sanity net alongside the accel tests: exact vs histogram vs
    // vectorized must find near-identical gains on a strongly separable
    // projected feature.
    let mut rng = Pcg64::new(33);
    let n = 5000;
    let labels: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
    let values: Vec<f32> = labels
        .iter()
        .map(|&l| rng.normal() as f32 + if l == 1 { 2.5 } else { 0.0 })
        .collect();
    let parent = [n / 2, n / 2];
    let mut scratch = SplitScratch::default();
    let mut gains = Vec::new();
    for method in [
        SplitMethod::Exact,
        SplitMethod::Histogram,
        SplitMethod::VectorizedHistogram,
    ] {
        let s = split::best_split(
            method,
            &values,
            &labels,
            &parent,
            SplitCriterion::Entropy,
            256,
            1,
            &mut rng,
            &mut scratch,
        )
        .unwrap();
        gains.push(s.gain);
    }
    let spread = gains.iter().cloned().fold(f64::MIN, f64::max)
        - gains.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 0.01, "method gains diverge: {gains:?}");
}

#[test]
fn active_set_partition_composes_with_training() {
    // ActiveSet splitting invariants under a real trained tree.
    let data = TrunkConfig {
        n_samples: 1000,
        n_features: 8,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(10));
    let cfg = ForestConfig {
        n_trees: 1,
        n_threads: 1,
        ..Default::default()
    };
    let out = train_forest_with_source(&data, &cfg, 1, ProjectionSource::SparseOblique);
    let tree = &out.forest.trees[0];
    // Route all samples: counts at leaves must sum to n.
    let mut row = Vec::new();
    let mut leaf_hits = std::collections::HashMap::new();
    for s in 0..data.n_samples() {
        data.row(s, &mut row);
        *leaf_hits.entry(tree.leaf_index(&row)).or_insert(0usize) += 1;
    }
    let total: usize = leaf_hits.values().sum();
    assert_eq!(total, data.n_samples());
    let _ = ActiveSet::full(4); // symbol use
}
