//! Storage-backend equivalence properties (the acceptance bar of the
//! chunked-columnar-storage refactor): a forest trained off the
//! memory-mapped `.sofc` backend must serialize to **byte-identical** v2
//! files as one trained off the in-memory backend — at any thread count,
//! for every split strategy, both growth modes, both `--hist_subtraction`
//! values and both `--simd` settings. The storage layer may only change
//! where slices come from, never a single bit that reaches the trainer.

use soforest::config::{ForestConfig, GrowthMode};
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::data::{colfile, csv, Dataset};
use soforest::forest::serialize::write_packed;
use soforest::forest::{Forest, PackedForest};
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;
use std::path::PathBuf;

fn trunk(n: usize, d: usize, seed: u64) -> Dataset {
    TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(seed))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

/// Write `data` to a column file and map it back.
fn mapped_twin(data: &Dataset, name: &str) -> (Dataset, PathBuf) {
    let path = tmp(name);
    colfile::write_dataset(data, &path).expect("pack");
    let mapped = colfile::load_mapped(&path).expect("map");
    assert_eq!(mapped.backend_name(), "mmap");
    (mapped, path)
}

/// Canonical v2 bytes of a forest (the serving format the acceptance bar
/// is stated in).
fn v2_bytes(forest: &Forest) -> Vec<u8> {
    let packed = PackedForest::from_forest(forest).expect("packable forest");
    let mut bytes = Vec::new();
    write_packed(&packed, &mut bytes).expect("in-memory serialization");
    bytes
}

const ALL_STRATEGIES: [SplitStrategy; 6] = [
    SplitStrategy::Exact,
    SplitStrategy::Histogram,
    SplitStrategy::VectorizedHistogram,
    SplitStrategy::Dynamic,
    SplitStrategy::DynamicVectorized,
    SplitStrategy::Hybrid,
];

#[test]
fn mapped_backend_forests_are_byte_identical_for_all_strategies_and_threads() {
    let ram = trunk(500, 10, 0x50FC);
    let (mapped, path) = mapped_twin(&ram, "soforest_storage_eq_strategies.sofc");
    for strategy in ALL_STRATEGIES {
        let train_with = |data: &Dataset, threads: usize| {
            let mut cfg = ForestConfig {
                n_trees: 3,
                n_threads: threads,
                strategy,
                growth: GrowthMode::Frontier,
                ..Default::default()
            };
            // Exercise all three tiers (and the deterministic accelerator
            // fallback for Hybrid — no device in the test env).
            cfg.thresholds.sort_below = 48;
            if strategy == SplitStrategy::Hybrid {
                cfg.thresholds.accel_above = 150;
            }
            v2_bytes(&train_forest(data, &cfg, 0xBEEF))
        };
        let reference = train_with(&ram, 1);
        for threads in [1, 2, 8] {
            assert_eq!(
                reference,
                train_with(&mapped, threads),
                "{strategy:?}: mmap-backend forest bytes differ at {threads} threads"
            );
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn mapped_backend_matches_across_growth_and_subtraction() {
    // Big enough that sibling pairs actually form under the lowered sort
    // crossover, so the subtraction path runs off the mapped backend too.
    let ram = trunk(2500, 10, 0x50FD);
    let (mapped, path) = mapped_twin(&ram, "soforest_storage_eq_growth.sofc");
    for growth in [GrowthMode::Depth, GrowthMode::Frontier] {
        let train_with = |data: &Dataset, threads: usize, sub: bool| {
            let mut cfg = ForestConfig {
                n_trees: 2,
                n_threads: threads,
                strategy: SplitStrategy::DynamicVectorized,
                growth,
                hist_subtraction: sub,
                ..Default::default()
            };
            cfg.thresholds.sort_below = 512;
            v2_bytes(&train_forest(data, &cfg, 0xAB))
        };
        let reference = train_with(&ram, 1, true);
        for threads in [1, 2, 8] {
            for sub in [true, false] {
                assert_eq!(
                    reference,
                    train_with(&mapped, threads, sub),
                    "{growth:?}: mmap bytes differ (threads={threads}, subtraction={sub})"
                );
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn binned_backend_forests_are_byte_identical_across_every_axis() {
    // The quantized-path determinism bar: for a fixed quantized input the
    // forest bytes are identical across thread counts, ram vs mmap
    // storage, fused vs classic split engines, and sibling-subtraction
    // on vs off. `quantized()` fits the same layouts `write_dataset_v2`
    // stores (same positional sampler, same fit), so the in-memory twin
    // and the mapped v2 file carry identical bin ids — everything the
    // trainer reads.
    let float = trunk(2500, 10, 0x50B1);
    let max_bins = 64;
    let ram_binned = float.quantized(max_bins);
    assert_eq!(ram_binned.backend_name(), "ram-binned");
    let path = tmp("soforest_storage_eq_binned.sofc");
    colfile::write_dataset_v2(&float, &path, max_bins).expect("pack v2");
    let mapped = colfile::load_mapped(&path).expect("map v2");
    assert_eq!(mapped.backend_name(), "mmap-binned");
    let train_with = |data: &Dataset, threads: usize, fused: bool, sub: bool, simd: bool| {
        let mut cfg = ForestConfig {
            n_trees: 2,
            n_threads: threads,
            strategy: SplitStrategy::DynamicVectorized,
            growth: GrowthMode::Frontier,
            fused,
            hist_subtraction: sub,
            simd,
            ..Default::default()
        };
        // Low enough that sibling pairs form and the histogram tier does
        // real work on this table (the binned selector lowers it 4x more).
        cfg.thresholds.sort_below = 512;
        v2_bytes(&train_forest(data, &cfg, 0xB1))
    };
    let reference = train_with(&ram_binned, 1, true, true, true);
    for threads in [1usize, 2, 8] {
        for fused in [true, false] {
            for sub in [true, false] {
                // The SIMD axis rides the backend loop: the dispatched
                // kernels (direct bin-id accumulate, routed fills,
                // subtraction, projection gathers) must leave the binned
                // path byte-identical too.
                for (name, data, simd) in [
                    ("ram-binned", &ram_binned, true),
                    ("ram-binned/scalar", &ram_binned, false),
                    ("mmap-binned", &mapped, true),
                    ("mmap-binned/scalar", &mapped, false),
                ] {
                    assert_eq!(
                        reference,
                        train_with(data, threads, fused, sub, simd),
                        "binned forest bytes differ \
                         ({name}, threads={threads}, fused={fused}, subtraction={sub})"
                    );
                }
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn csv_pack_stream_equals_in_memory_csv_load() {
    // gen -> CSV -> (a) slurp to RAM, (b) streaming pack -> mmap: the two
    // datasets must be bit-identical feature-for-feature (the pack path
    // parses the same text with the same f32 conversions).
    let data =
        trunk(1500, 6, 0x50FE).with_feature_names((0..6).map(|f| format!("c{f}")).collect());
    let csv_path = tmp("soforest_storage_eq.csv");
    let sofc_path = tmp("soforest_storage_eq_packed.sofc");
    csv::save_csv(&data, &csv_path).unwrap();
    let ram = csv::load_csv(&csv_path, csv::LabelColumn::Last, true).unwrap();
    let summary = colfile::pack_csv(&csv_path, &sofc_path, csv::LabelColumn::Last, true).unwrap();
    assert_eq!(summary.n_samples, ram.n_samples());
    assert_eq!(summary.n_features, ram.n_features());
    let mapped = colfile::load_mapped(&sofc_path).unwrap();
    assert_eq!(mapped.n_samples(), ram.n_samples());
    assert_eq!(mapped.n_classes(), ram.n_classes());
    assert_eq!(mapped.feature_names(), ram.feature_names());
    assert_eq!(mapped.labels(), ram.labels());
    for f in 0..ram.n_features() {
        let (a, b) = (ram.column(f), mapped.column(f));
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.to_bits(), y.to_bits(), "feature {f}");
        }
    }
    // And the forests trained off either are byte-identical.
    let cfg = ForestConfig {
        n_trees: 2,
        n_threads: 2,
        ..Default::default()
    };
    assert_eq!(
        v2_bytes(&train_forest(&ram, &cfg, 0xCAFE)),
        v2_bytes(&train_forest(&mapped, &cfg, 0xCAFE)),
        "csv-loaded vs streamed-packed forests differ"
    );
    std::fs::remove_file(&csv_path).ok();
    std::fs::remove_file(&sofc_path).ok();
}

#[test]
fn mapped_backend_serves_subset_transform_and_prediction_paths() {
    // The non-training consumers (subset carving, standardization,
    // row-gather prediction) read through the same chunk views.
    use soforest::data::transform::Standardizer;
    let ram = trunk(800, 5, 0x50FF);
    let (mapped, path) = mapped_twin(&ram, "soforest_storage_eq_aux.sofc");
    let idx: Vec<u32> = (0..800).step_by(3).collect();
    let (sa, sb) = (ram.subset(&idx), mapped.subset(&idx));
    assert_eq!(sa.labels(), sb.labels());
    for f in 0..sa.n_features() {
        assert_eq!(sa.column(f), sb.column(f), "subset feature {f}");
    }
    let (ta, tb) = (Standardizer::fit(&ram), Standardizer::fit(&mapped));
    for (x, y) in ta.means.iter().zip(&tb.means) {
        assert_eq!(x.to_bits(), y.to_bits(), "standardizer means diverge");
    }
    for (x, y) in ta.inv_stds.iter().zip(&tb.inv_stds) {
        assert_eq!(x.to_bits(), y.to_bits(), "standardizer stds diverge");
    }
    let mut ra = Vec::new();
    let mut rb = Vec::new();
    for s in (0..800).step_by(97) {
        ram.row(s, &mut ra);
        mapped.row(s, &mut rb);
        assert_eq!(ra, rb, "row {s}");
    }
    std::fs::remove_file(&path).ok();
}
