//! Property-based tests over coordinator/forest invariants (hand-rolled
//! randomized properties — the offline crate set has no proptest; each
//! property sweeps many seeded cases and shrinks by reporting the seed).

use soforest::config::ForestConfig;
use soforest::coordinator::{train_forest, train_forest_with_source};
use soforest::data::synth;
use soforest::data::{ActiveSet, Dataset};
use soforest::forest::tree::{Node, ProjectionSource};
use soforest::forest::Forest;
use soforest::projection::{ProjectionConfig, SamplerKind};
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn random_dataset(rng: &mut Pcg64) -> Dataset {
    let specs = [
        "trunk",
        "higgs",
        "susy",
        "credit-approval",
        "sparse-parity",
    ];
    let name = specs[rng.index(specs.len())];
    let n = 80 + rng.index(400);
    let spec = format!("{name}:{n}");
    synth::generate(&spec, rng).unwrap()
}

fn random_config(rng: &mut Pcg64) -> ForestConfig {
    let strategies = [
        SplitStrategy::Exact,
        SplitStrategy::Histogram,
        SplitStrategy::VectorizedHistogram,
        SplitStrategy::Dynamic,
        SplitStrategy::DynamicVectorized,
    ];
    let mut cfg = ForestConfig {
        n_trees: 1 + rng.index(4),
        n_threads: 1 + rng.index(3),
        strategy: strategies[rng.index(strategies.len())],
        n_bins: if rng.bernoulli(0.5) { 256 } else { 64 },
        min_leaf: 1 + rng.index(3),
        max_depth: if rng.bernoulli(0.3) {
            1 + rng.index(6)
        } else {
            0
        },
        bootstrap_fraction: 0.4 + rng.unif01() * 0.5,
        with_replacement: rng.bernoulli(0.5),
        sampler: if rng.bernoulli(0.5) {
            SamplerKind::Floyd
        } else {
            SamplerKind::Naive
        },
        projection: ProjectionConfig {
            row_factor: 1.0 + rng.unif01() * 2.0,
            nnz_factor: 1.0 + rng.unif01() * 4.0,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.thresholds.sort_below = [0, 64, 1024, usize::MAX][rng.index(4)];
    cfg
}

/// Structural invariants every trained forest must satisfy.
fn check_forest(forest: &Forest, data: &Dataset, cfg: &ForestConfig, seed: u64) {
    assert_eq!(forest.n_trees(), cfg.n_trees, "seed {seed}");
    let mut row = Vec::new();
    for tree in &forest.trees {
        // 1. Node links form a tree (every node reachable exactly once).
        let mut seen = vec![false; tree.nodes.len()];
        let mut stack = vec![0usize];
        while let Some(i) = stack.pop() {
            assert!(!seen[i], "seed {seed}: node {i} visited twice");
            seen[i] = true;
            match &tree.nodes[i] {
                Node::Split {
                    left,
                    right,
                    projection,
                    threshold,
                } => {
                    assert!(threshold.is_finite(), "seed {seed}");
                    assert!(!projection.terms.is_empty(), "seed {seed}");
                    for &(f, w) in &projection.terms {
                        assert!((f as usize) < data.n_features(), "seed {seed}");
                        assert!(w.is_finite() && w != 0.0, "seed {seed}");
                    }
                    stack.push(*left as usize);
                    stack.push(*right as usize);
                }
                Node::Leaf { posterior, n, .. } => {
                    let sum: f32 = posterior.iter().sum();
                    assert!(
                        (sum - 1.0).abs() < 1e-5 || *n == 0,
                        "seed {seed}: posterior sums to {sum}"
                    );
                    // Depth/min-leaf limits.
                    if cfg.max_depth == 0 && cfg.min_leaf == 1 {
                        // To-purity: leaf posterior is one-hot.
                        let nonzero = posterior.iter().filter(|&&p| p > 0.0).count();
                        assert!(nonzero <= 1, "seed {seed}: impure leaf {posterior:?}");
                    }
                }
            }
        }
        assert!(seen.iter().all(|&s| s), "seed {seed}: orphan node");
        // 2. Depth limit honored.
        if cfg.max_depth > 0 {
            assert!(
                tree.depth() <= cfg.max_depth,
                "seed {seed}: depth {} > {}",
                tree.depth(),
                cfg.max_depth
            );
        }
    }
    // 3. Prediction total probability.
    let mut proba = Vec::new();
    for s in (0..data.n_samples()).step_by(29) {
        data.row(s, &mut row);
        forest.predict_proba_row(&row, &mut proba);
        let sum: f32 = proba.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "seed {seed}: proba sum {sum}");
    }
}

#[test]
fn forest_invariants_hold_across_random_configs() {
    let mut meta = Pcg64::new(0xF0123);
    for case in 0..25u64 {
        let seed = meta.next_u64() % 100_000;
        let mut rng = Pcg64::new(seed);
        let data = random_dataset(&mut rng);
        let cfg = random_config(&mut rng);
        let forest = train_forest(&data, &cfg, seed);
        check_forest(&forest, &data, &cfg, seed);
        let _ = case;
    }
}

#[test]
fn axis_aligned_invariants_hold() {
    let mut meta = Pcg64::new(0xA0456);
    for _ in 0..8 {
        let seed = meta.next_u64() % 100_000;
        let mut rng = Pcg64::new(seed);
        let data = random_dataset(&mut rng);
        let mut cfg = random_config(&mut rng);
        cfg.strategy = SplitStrategy::Exact;
        let out = train_forest_with_source(
            &data,
            &cfg,
            seed,
            ProjectionSource::AxisAligned { mtry: 3 },
        );
        check_forest(&out.forest, &data, &cfg, seed);
        // All splits use single axis projections.
        for tree in &out.forest.trees {
            for node in &tree.nodes {
                if let Node::Split { projection, .. } = node {
                    assert_eq!(projection.terms.len(), 1, "seed {seed}");
                }
            }
        }
    }
}

#[test]
fn fused_and_classic_paths_build_identical_forests() {
    // The fused engine is the default training path; with the same seed it
    // must produce node-for-node the same forest as the classic
    // materialize-then-route path (`fused = off`) on every strategy,
    // layout and class count the random-config sweep generates.
    let mut meta = Pcg64::new(0xFA57ED);
    for _ in 0..10 {
        let seed = meta.next_u64() % 100_000;
        let mut rng = Pcg64::new(seed);
        let data = random_dataset(&mut rng);
        let mut cfg_fused = random_config(&mut rng);
        cfg_fused.fused = true;
        let mut cfg_classic = cfg_fused.clone();
        cfg_classic.fused = false;
        let a = train_forest(&data, &cfg_fused, seed);
        let b = train_forest(&data, &cfg_classic, seed);
        let mut row = Vec::new();
        for (ta, tb) in a.trees.iter().zip(&b.trees) {
            assert_eq!(
                ta.nodes.len(),
                tb.nodes.len(),
                "seed {seed}: tree shapes diverge between fused and classic"
            );
            for s in (0..data.n_samples()).step_by(7) {
                data.row(s, &mut row);
                assert_eq!(
                    ta.leaf_index(&row),
                    tb.leaf_index(&row),
                    "seed {seed}: sample {s} routed differently"
                );
            }
        }
    }
}

#[test]
fn to_purity_forests_memorize_their_bootstrap() {
    // With subsampling (no replacement), every tree perfectly classifies
    // its own training subset; the forest's training accuracy must beat the
    // majority class by a wide margin.
    let mut meta = Pcg64::new(0xBEEF);
    for _ in 0..6 {
        let seed = meta.next_u64() % 100_000;
        let mut rng = Pcg64::new(seed);
        let data = synth::generate("trunk:400:8", &mut rng).unwrap();
        let cfg = ForestConfig {
            n_trees: 10,
            n_threads: 2,
            with_replacement: false,
            bootstrap_fraction: 0.9,
            ..Default::default()
        };
        let forest = train_forest(&data, &cfg, seed);
        let acc = forest.accuracy(&data);
        assert!(acc > 0.9, "seed {seed}: to-purity train accuracy {acc}");
    }
}

#[test]
fn strategies_agree_on_strongly_separable_data() {
    // The paper's Table 4 claim, as a property: on separable data all
    // strategies reach (near-)identical holdout accuracy.
    let mut rng = Pcg64::new(0x7AB1E4);
    let data = synth::generate("trunk:1200:16", &mut rng).unwrap();
    let train_idx: Vec<u32> = (0..900).collect();
    let test_idx: Vec<u32> = (900..1200).collect();
    let train = data.subset(&train_idx);
    let test = data.subset(&test_idx);
    let mut accs = Vec::new();
    for strategy in [
        SplitStrategy::Exact,
        SplitStrategy::Histogram,
        SplitStrategy::DynamicVectorized,
    ] {
        let cfg = ForestConfig {
            n_trees: 20,
            n_threads: 2,
            strategy,
            ..Default::default()
        };
        accs.push(train_forest(&train, &cfg, 42).accuracy(&test));
    }
    let max = accs.iter().cloned().fold(f64::MIN, f64::max);
    let min = accs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max - min < 0.04, "strategy accuracies diverge: {accs:?}");
    assert!(min > 0.88, "accuracy too low: {accs:?}");
}

#[test]
fn empty_and_degenerate_inputs_are_rejected_or_handled() {
    // Constant features: forest still trains (single leaf if no signal).
    let data = Dataset::from_columns(
        vec![vec![1.0; 50], vec![2.0; 50]],
        (0..50).map(|i| (i % 2) as u16).collect(),
    );
    let cfg = ForestConfig {
        n_trees: 2,
        n_threads: 1,
        ..Default::default()
    };
    let f = train_forest(&data, &cfg, 1);
    // No split is possible on constant features.
    for tree in &f.trees {
        assert_eq!(tree.nodes.len(), 1, "constant features must yield a stump");
    }
    // ActiveSet edge cases.
    let empty = ActiveSet::default();
    assert!(empty.is_pure(&data));
    assert_eq!(empty.class_counts(&data), vec![0, 0]);
}

#[test]
fn tiny_datasets_train_without_panics() {
    for n in [2usize, 3, 5, 9] {
        let mut cols = vec![Vec::new(), Vec::new()];
        let mut labels = Vec::new();
        let mut rng = Pcg64::new(n as u64);
        for i in 0..n {
            cols[0].push(rng.normal() as f32);
            cols[1].push(rng.normal() as f32);
            labels.push((i % 2) as u16);
        }
        let data = Dataset::from_columns(cols, labels);
        for strategy in [SplitStrategy::Exact, SplitStrategy::DynamicVectorized] {
            let cfg = ForestConfig {
                n_trees: 2,
                n_threads: 1,
                strategy,
                bootstrap_fraction: 1.0,
                ..Default::default()
            };
            let f = train_forest(&data, &cfg, 7);
            assert_eq!(f.n_trees(), 2, "n={n}");
        }
    }
}
