//! Offline type-surface stub of the `xla` crate (xla-rs bindings).
//!
//! Purpose: keep the `pjrt`-gated runtime (`rust/src/runtime/pjrt.rs`)
//! *compiling* in environments without a libxla install — the CI step
//! `cargo check --features pjrt --all-targets` type-checks that surface on
//! every push, so it cannot silently rot behind the default stub build.
//!
//! This is NOT a working runtime: the only constructor
//! ([`PjRtClient::cpu`]) returns an error, so every caller takes its
//! existing "accelerator unavailable → CPU fallback" path. To run on real
//! PJRT, replace the `rust/vendor/xla-stub` path dependency in the root
//! `Cargo.toml` with the git `xla-rs` dependency and rebuild with
//! `--features pjrt`.
//!
//! The surface below mirrors exactly the subset of the xla-rs API that
//! `runtime/pjrt.rs` consumes; extend it in lockstep when that module
//! grows.

use std::path::Path;

/// Error type standing in for `xla::Error`; callers format it with `{:?}`.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} needs the real xla-rs bindings (libxla). Replace the \
         rust/vendor/xla-stub path dependency in Cargo.toml with the git xla \
         dependency and rebuild with --features pjrt"
    )))
}

/// Stands in for `xla::PjRtClient`.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stands in for `xla::HloModuleProto`.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stands in for `xla::XlaComputation`.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Stands in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Real signature is generic over buffer-convertible inputs; the stub
    /// leaves the parameter unconstrained so any call site type-checks.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stands in for `xla::PjRtBuffer`.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stands in for `xla::Literal`.
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Self {
        Self { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}
