//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build container has no network access to crates.io, so this vendored
//! shim implements exactly the subset of the anyhow 1.x API that soforest
//! uses: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! Errors are flattened to a single message string with `": "`-joined
//! context layers — the same rendering `anyhow` produces for `{:#}`.
//!
//! Drop-in caveat: unlike the real crate, [`Error`] does not capture
//! backtraces and does not support downcasting. Nothing in soforest relies
//! on either.

use std::fmt;

/// A flattened error: message plus any context layers prepended.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`. (Error itself deliberately does NOT
// implement std::error::Error, exactly like the real anyhow::Error, so this
// blanket impl cannot collide with `impl From<T> for T`.)
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut msg = e.to_string();
        let mut source = e.source();
        while let Some(s) = source {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            source = s.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result<T>` — a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error {
            msg: format!("{context}: {e}"),
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error {
            msg: format!("{}: {e}", f()),
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "boom"))
    }

    #[test]
    fn context_layers_join() {
        let e = io_err().context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: boom");
        let e = io_err()
            .with_context(|| format!("step {}", 2))
            .unwrap_err();
        assert_eq!(e.to_string(), "step 2: boom");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "42".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 42);
    }

    #[test]
    fn macros_format() {
        fn fails(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(())
        }
        assert!(fails(1).is_ok());
        assert_eq!(fails(3).unwrap_err().to_string(), "unlucky 3");
        assert_eq!(fails(11).unwrap_err().to_string(), "x too big: 11");
        let e = anyhow!("plain {}", "msg");
        assert_eq!(format!("{e:#}"), "plain msg");
    }
}
