//! Open-loop load harness for the serve tier: what do tail latency and
//! shed rate look like when clients send at a *fixed arrival rate*,
//! regardless of how fast the server answers?
//!
//! Closed-loop clients (send, wait, send) self-throttle under overload
//! and hide queueing collapse; this harness schedules every request up
//! front (request `j` fires at `t0 + j/qps`, round-robin over the
//! connections) and measures latency from the **scheduled** send time to
//! the response — so a server falling behind shows up as growing tail
//! latency and `!timeout` shed, exactly like coordinated-omission-safe
//! load generators do.
//!
//! Sweeps connections × target QPS × metrics on/off, each point against a
//! fresh server on an ephemeral port. Emits `BENCH_serve.json`
//! (client-side p50/p99/p999 latency, shed rate, achieved QPS, plus the
//! server's own histogram percentiles per point) for `ci/bench_gate.py`.
//! The metrics-off leg is the overhead baseline: with recording disabled
//! the same sweep measures what the histogram path costs.
//!
//! When the server answered everything (no timeouts/errors/refusals), the
//! server-reported p99 is cross-checked against the harness p99: in-server
//! time must sit at or below the client round trip (within one histogram
//! bucket of resolution plus scheduling slack). A violation warns by
//! default and fails under `SOFOREST_BENCH_SERVE_CHECK=1` (CI sets it).
//!
//! Overrides: `SOFOREST_BENCH_SERVE_SECS=2` (seconds per point),
//! `SOFOREST_BENCH_SERVE_QPS=500,2000`, `SOFOREST_BENCH_SERVE_CONNS=1,4`.

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::PackedForest;
use soforest::rng::Pcg64;
use soforest::serve::{percentile, serve_tcp, ServeConfig, ServeStats, Shutdown};
use std::fmt::Write as _;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Mutex;
use std::time::{Duration, Instant};

fn env_usize_list(name: &str, default: &[usize]) -> Vec<usize> {
    std::env::var(name)
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

/// What one connection observed, client-side.
#[derive(Default)]
struct ConnOutcome {
    sent: usize,
    ok: usize,
    timeouts: usize,
    errors: usize,
    /// The connection was refused with `!busy` (or never connected).
    refused: bool,
    /// Scheduled-send → response latency of the scored answers, us.
    lat_us: Vec<f64>,
}

/// One sweep point, aggregated over its connections.
struct Point {
    scheduled: usize,
    sent: usize,
    ok: usize,
    timeouts: usize,
    errors: usize,
    refused_conns: usize,
    lat_us: Vec<f64>,
    wall_s: f64,
    /// The server's own drained snapshot (lock-free histogram side).
    server: ServeStats,
}

/// Writer thread + in-thread reader for one connection. Responses are
/// 1:1 and in order with sent lines, so response `i` pairs with
/// `sched[i]` — latency is measured from that scheduled instant.
fn drive_conn(addr: &str, line: &str, sched: &[Duration], t0: Instant) -> ConnOutcome {
    let mut out = ConnOutcome::default();
    let stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => {
            out.refused = true;
            return out;
        }
    };
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            out.refused = true;
            return out;
        }
    };
    std::thread::scope(|scope| {
        let writer = scope.spawn(|| {
            let mut w = stream;
            let msg = format!("{line}\n");
            let mut sent = 0usize;
            for off in sched {
                // Open loop: sleep until the scheduled instant, never
                // until the previous response.
                if let Some(d) = (t0 + *off).checked_duration_since(Instant::now()) {
                    std::thread::sleep(d);
                }
                if w.write_all(msg.as_bytes()).is_err() {
                    break;
                }
                sent += 1;
            }
            let _ = w.shutdown(std::net::Shutdown::Write);
            sent
        });
        let mut r = BufReader::new(reader_stream);
        let mut text = String::new();
        let mut i = 0usize;
        loop {
            text.clear();
            match r.read_line(&mut text) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            let now = Instant::now();
            let resp = text.trim_end();
            if resp == "!busy" {
                out.refused = true;
                break;
            }
            if resp.starts_with("!timeout") {
                out.timeouts += 1;
            } else if resp.starts_with("!err") {
                out.errors += 1;
            } else {
                out.ok += 1;
                if let Some(off) = sched.get(i) {
                    let lat = now.saturating_duration_since(t0 + *off);
                    out.lat_us.push(lat.as_secs_f64() * 1e6);
                }
            }
            i += 1;
        }
        out.sent = writer.join().expect("writer thread");
    });
    out
}

/// Run one (conns, qps, metrics on/off) point against a fresh server.
fn drive_point(
    packed: &PackedForest,
    line: &str,
    conns: usize,
    qps: usize,
    secs: f64,
    metrics_on: bool,
) -> Point {
    let conns = conns.max(1);
    let pf = std::env::temp_dir().join(format!(
        "soforest_bench_serve_{conns}_{qps}_{}",
        if metrics_on { "on" } else { "off" }
    ));
    std::fs::remove_file(&pf).ok();
    let shutdown = Shutdown::new();
    let cfg = ServeConfig {
        // One worker per connection: the point measures batching and
        // scoring under arrival pressure, not pool starvation.
        workers: conns,
        queue_depth: conns,
        max_wait: Duration::from_micros(500),
        deadline: Duration::from_millis(100),
        drain: Duration::from_millis(500),
        port_file: Some(pf.clone()),
        metrics: metrics_on,
        ..Default::default()
    };
    let scheduled = ((qps as f64) * secs).round().max(1.0) as usize;
    let mut scheds: Vec<Vec<Duration>> = vec![Vec::new(); conns];
    for j in 0..scheduled {
        scheds[j % conns].push(Duration::from_secs_f64(j as f64 / qps as f64));
    }
    let outcomes: Mutex<Vec<ConnOutcome>> = Mutex::new(Vec::new());
    let mut wall_s = 0.0;
    let mut server_stats = ServeStats::default();
    std::thread::scope(|scope| {
        let server = scope.spawn(|| serve_tcp(packed, &cfg, &shutdown).expect("serve_tcp"));
        let addr = loop {
            match std::fs::read_to_string(&pf) {
                Ok(s) if !s.is_empty() => break s,
                _ => std::thread::sleep(Duration::from_millis(2)),
            }
        };
        let addr = addr.trim().to_string();
        // Common epoch slightly in the future so every connection is up
        // before its first scheduled request.
        let t0 = Instant::now() + Duration::from_millis(50);
        let clients: Vec<_> = scheds
            .iter()
            .map(|sched| {
                let addr = &addr;
                let outcomes = &outcomes;
                scope.spawn(move || {
                    let out = drive_conn(addr, line, sched, t0);
                    outcomes.lock().unwrap().push(out);
                })
            })
            .collect();
        for c in clients {
            c.join().expect("client thread");
        }
        wall_s = t0.elapsed().as_secs_f64();
        shutdown.request_stop();
        server_stats = server.join().expect("server thread");
        eprintln!("  [server] {}", server_stats.summary());
    });
    std::fs::remove_file(&pf).ok();
    let mut point = Point {
        scheduled,
        sent: 0,
        ok: 0,
        timeouts: 0,
        errors: 0,
        refused_conns: 0,
        lat_us: Vec::new(),
        wall_s,
        server: server_stats,
    };
    for o in outcomes.into_inner().expect("outcomes") {
        point.sent += o.sent;
        point.ok += o.ok;
        point.timeouts += o.timeouts;
        point.errors += o.errors;
        point.refused_conns += usize::from(o.refused);
        point.lat_us.extend(o.lat_us);
    }
    point.lat_us.sort_by(f64::total_cmp);
    point
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

fn main() {
    let secs: f64 = std::env::var("SOFOREST_BENCH_SERVE_SECS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3.0);
    let qps_sweep = env_usize_list("SOFOREST_BENCH_SERVE_QPS", &[1000, 4000]);
    let conns_sweep = env_usize_list("SOFOREST_BENCH_SERVE_CONNS", &[1, 4]);

    let d = 16;
    let data = TrunkConfig {
        n_samples: 4000,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(0x5E12E));
    let cfg = ForestConfig {
        n_trees: 32,
        ..Default::default()
    };
    let forest = train_forest(&data, &cfg, 9);
    let packed = PackedForest::from_forest(&forest).expect("pack forest");
    let mut row = Vec::new();
    data.row(0, &mut row);
    let line = row
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join(",");

    println!(
        "# serve tier under open-loop load (d={d}, 32 trees, {:.1} kB model, \
         {secs:.1}s per point, deadline 100ms)\n",
        packed.nbytes() as f64 / 1e3
    );
    let mut table = Table::new(&[
        "conns",
        "target_qps",
        "metrics",
        "scheduled",
        "answered",
        "p50_us",
        "p99_us",
        "p999_us",
        "srv_p99_us",
        "shed_rate",
        "achieved_qps",
    ]);
    let hard_check = std::env::var("SOFOREST_BENCH_SERVE_CHECK").is_ok_and(|v| v == "1");
    let mut check_failures: Vec<String> = Vec::new();
    let mut json_rows = String::new();
    let mut first = true;
    for &conns in &conns_sweep {
        for &qps in &qps_sweep {
            for metrics_on in [true, false] {
                let mode = if metrics_on { "on" } else { "off" };
                eprintln!("# point: conns={conns} target_qps={qps} metrics={mode}");
                let p = drive_point(&packed, &line, conns, qps, secs, metrics_on);
                let p50 = finite(percentile(&p.lat_us, 50.0));
                let p99 = finite(percentile(&p.lat_us, 99.0));
                let p999 = finite(percentile(&p.lat_us, 99.9));
                let srv = &p.server.latency;
                let srv_p50 = finite(srv.quantile(50.0));
                let srv_p99 = finite(srv.quantile(99.0));
                let srv_p999 = finite(srv.quantile(99.9));
                // Shed = every scheduled request that did not come back as
                // a scored answer: timeouts, refused connections, request
                // lines never sent or never answered.
                let shed_rate = 1.0 - p.ok as f64 / p.scheduled.max(1) as f64;
                let achieved = p.ok as f64 / p.wall_s.max(1e-9);
                // Cross-check (clean points only): the server's in-server
                // p99 must sit at or below the client round-trip p99,
                // within one histogram bucket of relative resolution
                // (3.125% half-width → 12.5% is generous) plus fixed
                // scheduling slack.
                if metrics_on
                    && p.timeouts == 0
                    && p.errors == 0
                    && p.refused_conns == 0
                    && srv.count > 0
                    && srv_p99 > p99 * 1.125 + 500.0
                {
                    let msg = format!(
                        "conns={conns} qps={qps}: server p99 {srv_p99:.0}us \
                         exceeds harness p99 {p99:.0}us + resolution"
                    );
                    eprintln!("# CHECK FAILED: {msg}");
                    check_failures.push(msg);
                }
                table.row(&[
                    conns.to_string(),
                    qps.to_string(),
                    mode.to_string(),
                    p.scheduled.to_string(),
                    p.ok.to_string(),
                    format!("{p50:.0}"),
                    format!("{p99:.0}"),
                    format!("{p999:.0}"),
                    format!("{srv_p99:.0}"),
                    format!("{shed_rate:.4}"),
                    format!("{achieved:.0}"),
                ]);
                if !first {
                    json_rows.push_str(",\n");
                }
                first = false;
                let _ = write!(
                    json_rows,
                    "    {{\"conns\": {conns}, \"target_qps\": {qps}, \
                     \"metrics\": \"{mode}\", \"secs\": {secs}, \
                     \"scheduled\": {}, \"sent\": {}, \"answered\": {}, \
                     \"timeouts\": {}, \"errors\": {}, \"refused_conns\": {}, \
                     \"p50_us\": {p50:.1}, \"p99_us\": {p99:.1}, \"p999_us\": {p999:.1}, \
                     \"server_p50_us\": {srv_p50:.1}, \"server_p99_us\": {srv_p99:.1}, \
                     \"server_p999_us\": {srv_p999:.1}, \"server_samples\": {}, \
                     \"shed_rate\": {shed_rate:.6}, \"achieved_qps\": {achieved:.1}}}",
                    p.scheduled, p.sent, p.ok, p.timeouts, p.errors, p.refused_conns, srv.count,
                );
            }
        }
    }
    table.print();

    let json = format!(
        "{{\n  \"bench\": \"serve\",\n  \"unit\": \"us\",\n  \
         \"results\": [\n{json_rows}\n  ]\n}}\n"
    );
    let out = "BENCH_serve.json";
    match std::fs::write(out, &json) {
        Ok(()) => println!("\n# wrote {out}"),
        Err(e) => eprintln!("\n# could not write {out}: {e}"),
    }
    if !check_failures.is_empty() && hard_check {
        eprintln!("# {} server-vs-harness p99 check(s) failed", check_failures.len());
        std::process::exit(1);
    }
}
