//! Classic vs fused node-split cost at several node cardinalities.
//!
//! Measures the full per-node work the trainer actually does for
//! histogram-method nodes — gather (apply) + route + accumulate + edge
//! scan over all candidate projections — for both engines, and emits
//! `BENCH_node_split.json` so the perf trajectory is machine-readable
//! across PRs. The acceptance bar for the fused engine is ≥ 1.2×
//! ns/sample on nodes of ≥ 4096 samples.
//!
//! `SOFOREST_BENCH_SIZES=1024,4096` overrides the cardinality sweep.
//!
//! Each cardinality is measured twice — `simd: "on"` (the runtime
//! dispatcher's best table for this CPU) and `simd: "off"` (forced scalar
//! reference kernels) — so the JSON records what vectorization buys on the
//! CI hardware and the gate can track both paths independently.

use soforest::bench::{BenchOpts, Table};
use soforest::calibrate::{classic_node_cost_ns, fused_node_cost_ns, synthetic_workload};
use soforest::split::histogram::Routing;
use soforest::split::{simd, SplitMethod};
use std::fmt::Write as _;

fn main() {
    let sizes: Vec<usize> = std::env::var("SOFOREST_BENCH_SIZES")
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|s| s.trim().parse().ok())
                .collect()
        })
        .unwrap_or_else(|| vec![1024, 4096, 16_384, 65_536]);
    let d = 256;
    // ≈ 1.5·√d candidate projections, the paper's default node workload.
    let p = 24;
    let n_bins = 256;
    let opts = BenchOpts::default();

    println!(
        "# node-split engines: classic (materialize-then-route) vs fused, \
         d={d} p={p} bins={n_bins} (dispatch: {})\n",
        simd::active_isa().name()
    );
    let mut table = Table::new(&[
        "n",
        "simd",
        "classic_ns/smp",
        "fused_ns/smp",
        "speedup",
    ]);
    let mut json_rows = String::new();
    let mut first = true;
    for (k, &n) in sizes.iter().enumerate() {
        let w = synthetic_workload(n, p, d, 0xBE7C4 + k as u64);
        for simd_on in [true, false] {
            simd::set_enabled(simd_on);
            let simd_name = if simd_on { "on" } else { "off" };
            let classic =
                classic_node_cost_ns(&w, SplitMethod::VectorizedHistogram, n_bins, &opts);
            let fused = fused_node_cost_ns(&w, n_bins, Routing::TwoLevel, &opts);
            let classic_per_sample = classic / n as f64;
            let fused_per_sample = fused / n as f64;
            let speedup = classic / fused;
            table.row(&[
                n.to_string(),
                simd_name.to_string(),
                format!("{classic_per_sample:.3}"),
                format!("{fused_per_sample:.3}"),
                format!("{speedup:.2}x"),
            ]);
            if !first {
                json_rows.push_str(",\n");
            }
            first = false;
            let _ = write!(
                json_rows,
                "    {{\"n\": {n}, \"simd\": \"{simd_name}\", \"p\": {p}, \"n_bins\": {n_bins}, \
                 \"classic_ns_per_sample\": {classic_per_sample:.4}, \
                 \"fused_ns_per_sample\": {fused_per_sample:.4}, \
                 \"speedup\": {speedup:.4}}}"
            );
        }
    }
    simd::set_enabled(true);
    table.print();

    let json = format!(
        "{{\n  \"bench\": \"node_split\",\n  \"unit\": \"ns_per_sample_per_projection\",\n  \
         \"d\": {d},\n  \"projections\": {p},\n  \"results\": [\n{json_rows}\n  ]\n}}\n"
    );
    let out = "BENCH_node_split.json";
    match std::fs::write(out, &json) {
        Ok(()) => println!("\n# wrote {out}"),
        Err(e) => eprintln!("\n# could not write {out}: {e}"),
    }
}
