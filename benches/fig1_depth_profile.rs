//! Figure 1: training runtime by tree depth — exact vs histogram vs dynamic.
//!
//! Paper setup: 1M samples × 4096 features. Scaled for this testbed via
//! SOFOREST_BENCH_N / SOFOREST_BENCH_D (defaults 40000 × 256; the shape —
//! histograms cheap at shallow depths, exact cheap at deep depths, dynamic
//! tracking the lower envelope — is what must reproduce).

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let n = env_usize("SOFOREST_BENCH_N", 40_000);
    let d = env_usize("SOFOREST_BENCH_D", 256);
    let trees = env_usize("SOFOREST_BENCH_TREES", 2);
    println!("# Fig 1: runtime by depth, trunk {n}x{d}, {trees} trees/strategy\n");

    let data = TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(1));

    let strategies = [
        ("exact", SplitStrategy::Exact),
        ("histogram", SplitStrategy::Histogram),
        ("dynamic", SplitStrategy::DynamicVectorized),
    ];
    let mut profiles = Vec::new();
    for (name, strategy) in strategies {
        let cfg = ForestConfig {
            n_trees: trees,
            n_threads: 1,
            strategy,
            instrument: true,
            ..Default::default()
        };
        let out = train_forest_with_source(&data, &cfg, 7, ProjectionSource::SparseOblique);
        println!("{name}: total {:.2}s, {} nodes", out.wall_s, out.stats.n_nodes);
        profiles.push((name, out.stats));
    }

    let max_depth = profiles.iter().map(|(_, s)| s.by_depth.len()).max().unwrap();
    let mut table = Table::new(&["depth", "exact_ms", "histogram_ms", "dynamic_ms", "nodes_dyn"]);
    for depth in 0..max_depth {
        let ms = |i: usize| -> String {
            profiles[i]
                .1
                .by_depth
                .get(depth)
                .map_or("-".into(), |d| format!("{:.3}", d.total_ns as f64 / 1e6))
        };
        let nodes = profiles[2]
            .1
            .by_depth
            .get(depth)
            .map_or(0, |d| d.nodes_by_method.iter().sum::<u64>());
        table.row(&[
            depth.to_string(),
            ms(0),
            ms(1),
            ms(2),
            nodes.to_string(),
        ]);
    }
    println!();
    table.print();

    // Shape check (paper Fig 1): histograms beat exact near the root,
    // exact beats histograms deep down, dynamic ~tracks the minimum.
    let sum_range = |i: usize, r: std::ops::Range<usize>| -> f64 {
        r.filter_map(|d| profiles[i].1.by_depth.get(d))
            .map(|d| d.total_ns as f64)
            .sum()
    };
    let deep_start = 12.min(max_depth.saturating_sub(2));
    let (ex_top, hist_top) = (sum_range(0, 0..4), sum_range(1, 0..4));
    let (ex_deep, hist_deep) = (
        sum_range(0, deep_start..max_depth),
        sum_range(1, deep_start..max_depth),
    );
    let dyn_total = sum_range(2, 0..max_depth);
    let best_total = sum_range(0, 0..max_depth).min(sum_range(1, 0..max_depth));
    println!("\n# shape: top-4-depth   exact {:.1}ms vs hist {:.1}ms (hist should win)", ex_top / 1e6, hist_top / 1e6);
    println!("# shape: deep (>={deep_start})  exact {:.1}ms vs hist {:.1}ms (exact should win)", ex_deep / 1e6, hist_deep / 1e6);
    println!("# shape: dynamic {:.1}ms vs best-pure {:.1}ms (dynamic <= ~best)", dyn_total / 1e6, best_total / 1e6);
}
