//! Figure 8: thread scalability of vectorized dynamic-histogram training.
//!
//! Paper: 1–32 threads on 16 physical cores, 100k × 4096 — near-perfect
//! scaling to the core count, then flat/regressing from cache interference.
//! This container exposes a single core, so wall-clock speedup saturates at
//! ~1× by construction; to still validate the coordinator we additionally
//! report total CPU work per thread count (tree-train nanoseconds summed
//! across workers): flat total work across thread counts = no coordination
//! overhead, which is the property the paper's near-perfect scaling
//! certifies on real cores.

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn main() {
    let n = std::env::var("SOFOREST_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20_000);
    let d = 512;
    let trees = 8;
    let cores = std::thread::available_parallelism().map_or(1, |c| c.get());
    println!("# Fig 8: scalability, trunk {n}x{d}, {trees} trees ({cores} physical cores visible)\n");

    let data = TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(8));

    let mut base_wall = None;
    let mut table = Table::new(&["threads", "wall_s", "speedup", "overhead_vs_1t"]);
    for threads in [1usize, 2, 4, 8] {
        let cfg = ForestConfig {
            n_trees: trees,
            n_threads: threads,
            strategy: SplitStrategy::DynamicVectorized,
            ..Default::default()
        };
        let out = train_forest_with_source(&data, &cfg, 42, ProjectionSource::SparseOblique);
        let base_w = *base_wall.get_or_insert(out.wall_s);
        table.row(&[
            threads.to_string(),
            format!("{:.2}", out.wall_s),
            format!("{:.2}", base_w / out.wall_s),
            format!("{:+.1}%", (out.wall_s / base_w - 1.0) * 100.0),
        ]);
    }
    table.print();
    println!("\n# paper shape: speedup ~= min(threads, cores), flat beyond the core count.");
    println!("# This container has {cores} core(s): expected speedup here is ~1x at every");
    println!("# thread count; the reproduction target is overhead_vs_1t ~= 0% (total work");
    println!("# unchanged under time-slicing => no lock contention / no coordination cost).");
    println!("# Per-tree *wall* under oversubscription inflates ~linearly with threads —");
    println!("# that is scheduler time-slicing, not coordinator overhead.");
}
