//! Table 2 + Figure 7: end-to-end CPU training time across the paper's
//! four performance datasets for Exact vs Dynamic Hist. vs Vectorized
//! Dynamic Hist., plus the axis-aligned RF bar from Fig 7.
//!
//! Paper (48 cores, 240 trees, full-size UCI data):
//!   Higgs 663.66 / 449.48 / 341.28 s ; SUSY 245.49 / 161.45 / 116.34 ;
//!   Epsilon 107.52 / 85.14 / 69.00 ; Trunk-1M 408.56 / 301.99 / 242.67.
//! Scaled here (single core, synthetic analogs, SOFOREST_BENCH_SCALE to
//! grow): the *normalized* columns (Fig 7) are the reproduction target —
//! dynamic ≈ 0.70–0.80× exact, vectorized dynamic ≈ 0.50–0.65×, and SO
//! as fast or faster than axis-aligned RF.

use soforest::bench::Table;
use soforest::calibrate;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth;
use soforest::forest::axis_aligned;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::histogram::Routing;
use soforest::split::SplitStrategy;

fn main() {
    let scale: f64 = std::env::var("SOFOREST_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let trees = std::env::var("SOFOREST_BENCH_TREES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8usize);
    let sz = |base: usize| ((base as f64 * scale) as usize).max(500);

    // Scaled-down analogs of Table 1 (full sizes: 11M/5M/400k/1M).
    let datasets = [
        ("higgs", format!("higgs:{}", sz(60_000))),
        ("susy", format!("susy:{}", sz(100_000))),
        ("epsilon", format!("epsilon:{}", sz(8_000))),
        ("trunk", format!("trunk:{}:256", sz(50_000))),
    ];

    let sort_below = calibrate::calibrate_sort_threshold(256, Routing::TwoLevel);
    let sort_below_bin = calibrate::calibrate_sort_threshold(256, Routing::BinarySearch);
    println!(
        "# Table 2 / Fig 7: end-to-end training, {trees} trees, 1 thread, crossover={} (vec) {} (bin)\n",
        sort_below, sort_below_bin
    );

    let mut table = Table::new(&[
        "dataset",
        "exact_s",
        "dyn_s",
        "vecdyn_s",
        "rf_s",
        "dyn_norm",
        "vecdyn_norm",
        "rf_norm",
    ]);
    for (name, spec) in &datasets {
        let data = synth::generate(spec, &mut Pcg64::new(11)).unwrap();
        let run = |strategy: SplitStrategy, sb: usize| -> f64 {
            let mut cfg = ForestConfig {
                n_trees: trees,
                n_threads: 1,
                strategy,
                ..Default::default()
            };
            cfg.thresholds.sort_below = sb;
            train_forest_with_source(&data, &cfg, 42, ProjectionSource::SparseOblique).wall_s
        };
        let exact = run(SplitStrategy::Exact, usize::MAX);
        let dynamic = run(SplitStrategy::Dynamic, sort_below_bin.min(1 << 14));
        let vecdyn = run(
            SplitStrategy::DynamicVectorized,
            sort_below.min(1 << 14),
        );
        let t0 = std::time::Instant::now();
        let cfg = ForestConfig {
            n_trees: trees,
            n_threads: 1,
            ..Default::default()
        };
        let _rf = axis_aligned::train_rf(&data, &cfg, 42);
        let rf = t0.elapsed().as_secs_f64();
        table.row(&[
            name.to_string(),
            format!("{exact:.2}"),
            format!("{dynamic:.2}"),
            format!("{vecdyn:.2}"),
            format!("{rf:.2}"),
            format!("{:.3}", dynamic / exact),
            format!("{:.3}", vecdyn / exact),
            format!("{:.3}", rf / exact),
        ]);
        eprintln!("[{name}] done");
    }
    table.print();
    println!("\n# paper Fig 7 shape: dyn_norm ~0.7-0.8, vecdyn_norm ~0.4-0.65 (improves with n), rf >= vecdyn");
}
