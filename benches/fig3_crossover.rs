//! Figure 3: split-cost microbenchmarks vs node cardinality — the curves
//! behind the §4.1 calibration. Top panel: exact (sort) vs histogram
//! (binary-search routing) vs vectorized histogram on the CPU. Bottom
//! panel: CPU vectorized vs accelerator (PJRT executable), when artifacts
//! are present.
//!
//! Paper shapes: sort wins below a few hundred samples; histograms win
//! above (~350–1300 crossover); the accelerator wins only at tens of
//! thousands (~29 000 on the paper's GPU — higher here because the PJRT
//! path re-transfers node data per call where the paper preloads the
//! dataset on device).

use soforest::accel::NodeSplitAccel;
use soforest::bench::{measure, BenchOpts, Table};
use soforest::calibrate::split_cost_ns;
use soforest::rng::Pcg64;
use soforest::split::histogram::build_boundaries;
use soforest::split::{SplitMethod, SplitScratch};
use std::path::Path;

fn main() {
    let opts = BenchOpts::default();
    println!("# Fig 3 (top): per-split cost (us) vs node cardinality\n");
    let mut table = Table::new(&["n", "sort_us", "hist_us", "vhist_us", "winner"]);
    let mut crossover_seen = None;
    for exp in 4..=17 {
        let n = 1usize << exp;
        let sort = split_cost_ns(n, SplitMethod::Exact, 256, &opts);
        let hist = split_cost_ns(n, SplitMethod::Histogram, 256, &opts);
        let vhist = split_cost_ns(n, SplitMethod::VectorizedHistogram, 256, &opts);
        let winner = if sort <= hist.min(vhist) { "sort" } else if vhist <= hist { "vhist" } else { "hist" };
        if winner != "sort" && crossover_seen.is_none() {
            crossover_seen = Some(n);
        }
        table.row(&[
            n.to_string(),
            format!("{:.2}", sort / 1e3),
            format!("{:.2}", hist / 1e3),
            format!("{:.2}", vhist / 1e3),
            winner.into(),
        ]);
    }
    table.print();
    println!(
        "\n# sort->histogram crossover ~ {} (paper: 350-1300 depending on machine)",
        crossover_seen.map_or("none".into(), |n| n.to_string())
    );

    // Bottom panel: accelerator.
    let artifacts = std::env::var("SOFOREST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    match NodeSplitAccel::try_load(Path::new(&artifacts)) {
        Err(e) => println!("\n# Fig 3 (bottom) skipped: {e}"),
        Ok(mut accel) => {
            println!("\n# Fig 3 (bottom): node evaluation (p=16 projections), CPU vs accelerator (ms)\n");
            let p = 16;
            let mut table = Table::new(&["n", "cpu_ms", "accel_ms", "winner"]);
            for exp in 10..=16 {
                let n = 1usize << exp;
                let mut rng = Pcg64::new(n as u64);
                let labels: Vec<u16> = (0..n).map(|i| (i % 2) as u16).collect();
                let values_one: Vec<f32> = labels
                    .iter()
                    .map(|&l| rng.normal() as f32 + if l == 1 { 0.8 } else { 0.0 })
                    .collect();
                let parent = [n - n / 2, n / 2];
                let mut scratch = SplitScratch::default();
                let cpu_ns = measure(&opts, || {
                    for _ in 0..p {
                        std::hint::black_box(soforest::split::best_split(
                            SplitMethod::VectorizedHistogram,
                            &values_one,
                            &labels,
                            &parent,
                            soforest::split::SplitCriterion::Entropy,
                            256,
                            1,
                            &mut rng,
                            &mut scratch,
                        ));
                    }
                })
                .median_ns;
                let mut values = Vec::with_capacity(p * n);
                let mut bounds = Vec::with_capacity(p * 256);
                for _ in 0..p {
                    values.extend_from_slice(&values_one);
                    assert!(build_boundaries(&values_one, 256, &mut rng, &mut scratch));
                    bounds.extend_from_slice(&scratch.boundaries);
                }
                let accel_ns = measure(&opts, || {
                    std::hint::black_box(
                        accel.execute_node(&values, p, n, &labels, &bounds, 256).unwrap(),
                    )
                })
                .median_ns;
                table.row(&[
                    n.to_string(),
                    format!("{:.3}", cpu_ns / 1e6),
                    format!("{:.3}", accel_ns / 1e6),
                    if accel_ns < cpu_ns { "accel" } else { "cpu" }.into(),
                ]);
            }
            table.print();
            println!("\n# accelerator has a fixed invocation cost amortized only at large n (paper: >29000)");
        }
    }
}
