//! Figure 5: runtime breakdown of histogram splitting by component
//! (projection apply vs histogram build vs split evaluation) across tree
//! depth.
//!
//! Paper shape: histogram construction dominates at every depth; sparse
//! projection access grows (relatively) deeper in the tree.

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn main() {
    let n = std::env::var("SOFOREST_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40_000);
    let d = 256;
    println!("# Fig 5: component breakdown (histogram splitting), trunk {n}x{d}\n");

    let data = TrunkConfig {
        n_samples: n,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(4));
    let cfg = ForestConfig {
        n_trees: 2,
        n_threads: 1,
        strategy: SplitStrategy::Histogram,
        instrument: true,
        // This figure decomposes the *classic* pipeline into apply/build/
        // eval components; the fused engine collapses apply+build into one
        // FusedSplit timer, so run the materializing path here. The fused
        // engine's profile is covered by benches/fused_pipeline.rs.
        fused: false,
        ..Default::default()
    };
    let out = train_forest_with_source(&data, &cfg, 9, ProjectionSource::SparseOblique);

    // Component indices: 0 sample_projections, 1 apply, 2 build, 3 eval, 4 partition.
    let mut table = Table::new(&[
        "depth",
        "sample_ms",
        "project_ms",
        "hist_ms",
        "eval+part_ms",
        "hist_frac",
    ]);
    let (mut tot_proj, mut tot_hist) = (0f64, 0f64);
    for (depth, ds) in out.stats.by_depth.iter().enumerate() {
        let c = &ds.component_ns;
        let total: u64 = c.iter().sum();
        if total == 0 {
            continue;
        }
        tot_proj += c[1] as f64;
        tot_hist += c[2] as f64;
        table.row(&[
            depth.to_string(),
            format!("{:.3}", c[0] as f64 / 1e6),
            format!("{:.3}", c[1] as f64 / 1e6),
            format!("{:.3}", c[2] as f64 / 1e6),
            format!("{:.3}", (c[3] + c[4]) as f64 / 1e6),
            format!("{:.2}", c[2] as f64 / total as f64),
        ]);
    }
    table.print();
    println!(
        "\n# totals: projection {:.1}ms vs histogram {:.1}ms — histogram construction dominates (paper Fig 5)",
        tot_proj / 1e6,
        tot_hist / 1e6
    );
}
