//! Appendix A.1: projection-matrix sampling — naive Θ(rows·d) Bernoulli
//! masking vs the Floyd/Binomial O(nnz) sampler.
//!
//! Paper: the naive sampler was 80% of SO-YDF's runtime on wide data; the
//! Floyd substitution cut total runtime by 33%. Reproduction target: the
//! Floyd sampler's cost is ~flat in d while naive grows linearly; ≥10×
//! faster by d = 64k.

use soforest::bench::{measure, BenchOpts, Table};
use soforest::projection::{sample_floyd, sample_naive, ProjectionConfig};
use soforest::rng::Pcg64;

fn main() {
    let opts = BenchOpts::default();
    let cfg = ProjectionConfig::default();
    println!("# Appendix A.1: projection sampling cost per node (us)\n");
    let mut table = Table::new(&["d", "rows", "nnz", "naive_us", "floyd_us", "speedup"]);
    for exp in [8u32, 10, 12, 14, 16] {
        let d = 1usize << exp;
        let mut rng = Pcg64::new(d as u64);
        let t_naive = measure(&opts, || std::hint::black_box(sample_naive(&mut rng, d, &cfg)));
        let t_floyd = measure(&opts, || std::hint::black_box(sample_floyd(&mut rng, d, &cfg)));
        table.row(&[
            d.to_string(),
            cfg.n_rows(d).to_string(),
            cfg.n_nonzeros(d).to_string(),
            format!("{:.2}", t_naive.median_us()),
            format!("{:.2}", t_floyd.median_us()),
            format!("{:.1}x", t_naive.median_ns / t_floyd.median_ns),
        ]);
    }
    table.print();
    println!("\n# paper shape: naive grows ~linearly in d; floyd ~O(sqrt d); >440k-feature datasets need floyd");
}
