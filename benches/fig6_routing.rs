//! Figure 6: bin-routing throughput — binary search vs the branchless
//! two-level compare, at 256 bins (16×16) and 64 bins (8×8).
//!
//! Paper claim (§4.2): the vectorized routing is ~2× faster than binary
//! search for 256-bin histograms and also wins at 64 bins.

use soforest::bench::{measure, BenchOpts, Table};
use soforest::rng::Pcg64;
use soforest::split::histogram::{route_binary_search, route_upper_bound_branchy};
use soforest::split::vectorized::{build_coarse, route_16x16, route_8x8, TwoLevelLayout};

fn padded_boundaries(rng: &mut Pcg64, n_bins: usize) -> Vec<f32> {
    let mut b: Vec<f32> = (0..n_bins - 1).map(|_| rng.normal() as f32).collect();
    b.sort_unstable_by(f32::total_cmp);
    b.push(f32::INFINITY);
    b
}

fn main() {
    let opts = BenchOpts::default();
    println!("# Fig 6: routing throughput (Melem/s), higher is better\n");
    let mut table = Table::new(&[
        "n_values",
        "bins",
        "upper_bound",   // branchy — the paper's YDF baseline
        "branchless_bs", // rust partition_point (cmov)
        "two_level",     // §4.2 vectorized
        "vs_upper",
        "vs_branchless",
    ]);

    for &n in &[1_000usize, 10_000, 100_000, 1_000_000] {
        let mut rng = Pcg64::new(n as u64);
        let values: Vec<f32> = (0..n).map(|_| (rng.normal() * 1.3) as f32).collect();
        for &bins in &[64usize, 256] {
            let bounds = padded_boundaries(&mut rng, bins);
            let layout = TwoLevelLayout::for_bins(bins).unwrap();
            let mut coarse = Vec::new();
            build_coarse(&bounds, layout, &mut coarse);
            let n_real = bins - 1;

            let t_branchy = measure(&opts, || {
                let mut acc = 0usize;
                for &v in &values {
                    acc += route_upper_bound_branchy(v, &bounds, n_real);
                }
                acc
            });
            let t_bin = measure(&opts, || {
                let mut acc = 0usize;
                for &v in &values {
                    acc += route_binary_search(v, &bounds, n_real);
                }
                acc
            });
            let t_vec = measure(&opts, || {
                let mut acc = 0usize;
                if bins == 256 {
                    for &v in &values {
                        acc += route_16x16(v, &coarse, &bounds);
                    }
                } else {
                    for &v in &values {
                        acc += route_8x8(v, &coarse, &bounds);
                    }
                }
                acc
            });
            let mps = |t: f64| n as f64 / t * 1e3; // ns -> Melem/s
            table.row(&[
                n.to_string(),
                bins.to_string(),
                format!("{:.1}", mps(t_branchy.median_ns)),
                format!("{:.1}", mps(t_bin.median_ns)),
                format!("{:.1}", mps(t_vec.median_ns)),
                format!("{:.2}x", t_branchy.median_ns / t_vec.median_ns),
                format!("{:.2}x", t_bin.median_ns / t_vec.median_ns),
            ]);
        }
    }
    table.print();
    println!("\n# paper: ~2x for 256 bins vs std::upper_bound (branchy) — vs_upper is the faithful");
    println!("# comparison; vs_branchless shows the gap to rust's cmov binary search as well.");
}
