//! Serving throughput: row-at-a-time vs cache-blocked batched vs
//! multi-threaded batched prediction through [`PackedForest`].
//!
//! Emits `BENCH_predict.json` (rows/sec per mode) so the serving-perf
//! trajectory is machine-readable across PRs, next to
//! `BENCH_node_split.json` for training. The acceptance bar for the
//! cache-blocked batch path is ≥ 1.0× the row-at-a-time baseline at every
//! batch size (it removes per-row accumulator allocation and re-streams
//! neither rows nor accumulator per tree).
//!
//! `SOFOREST_BENCH_PREDICT_ROWS=4096,65536` overrides the batch sweep;
//! `SOFOREST_BENCH_THREADS=8` pins the multi-threaded shard count.

use soforest::bench::{measure, BenchOpts, Table};
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::PackedForest;
use soforest::rng::Pcg64;
use std::fmt::Write as _;

fn main() {
    let sizes: Vec<usize> = std::env::var("SOFOREST_BENCH_PREDICT_ROWS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![1024, 16_384, 65_536]);
    let threads: usize = std::env::var("SOFOREST_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| std::thread::available_parallelism().map_or(4, |n| n.get()));
    let d = 32;
    let n_trees = 48;
    let max_rows = sizes.iter().copied().max().unwrap_or(1024);

    // One forest, one pool of rows (cycled when a sweep point exceeds the
    // training set); each sweep point scores a prefix.
    let mut rng = Pcg64::new(0xF0E57);
    let data = TrunkConfig {
        n_samples: 20_000,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut rng);
    let cfg = ForestConfig {
        n_trees,
        ..Default::default()
    };
    let forest = train_forest(&data, &cfg, 9);
    let packed = PackedForest::from_forest(&forest).expect("pack forest");
    let n_data = data.n_samples();
    let mut rows = vec![0f32; max_rows * d];
    let mut row = Vec::new();
    for s in 0..max_rows {
        data.row(s % n_data, &mut row);
        rows[s * d..(s + 1) * d].copy_from_slice(&row);
    }

    println!(
        "# packed-forest prediction: rowwise vs batched vs batched x{threads} threads \
         (d={d}, {n_trees} trees, {:.0} kB model)\n",
        packed.nbytes() as f64 / 1e3
    );
    let mut table = Table::new(&[
        "rows",
        "rowwise_rows/s",
        "batched_rows/s",
        "batched_mt_rows/s",
        "batch_speedup",
        "mt_speedup",
    ]);
    let opts = BenchOpts::default();
    let mut json_rows = String::new();
    for (k, &n) in sizes.iter().enumerate() {
        let n = n.min(max_rows);
        let slice = &rows[..n * d];
        let rowwise = measure(&opts, || {
            let mut proba = Vec::new();
            let mut preds: Vec<u16> = Vec::with_capacity(n);
            for r in slice.chunks_exact(d) {
                packed.predict_proba_row(r, &mut proba);
                let p = proba
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map_or(0, |(i, _)| i as u16);
                preds.push(p);
            }
            preds
        });
        let batched = measure(&opts, || packed.predict_batch(slice, n));
        let batched_mt = measure(&opts, || packed.predict_batch_parallel(slice, n, threads));
        let rps = |t: &soforest::bench::Timing| n as f64 / t.median_s();
        let (r_row, r_batch, r_mt) = (rps(&rowwise), rps(&batched), rps(&batched_mt));
        table.row(&[
            n.to_string(),
            format!("{r_row:.0}"),
            format!("{r_batch:.0}"),
            format!("{r_mt:.0}"),
            format!("{:.2}x", r_batch / r_row),
            format!("{:.2}x", r_mt / r_row),
        ]);
        if k > 0 {
            json_rows.push_str(",\n");
        }
        let _ = write!(
            json_rows,
            "    {{\"rows\": {n}, \"d\": {d}, \"trees\": {n_trees}, \
             \"rowwise_rows_per_s\": {r_row:.1}, \
             \"batched_rows_per_s\": {r_batch:.1}, \
             \"batched_mt_rows_per_s\": {r_mt:.1}, \
             \"threads\": {threads}, \
             \"batch_speedup\": {:.4}, \"mt_speedup\": {:.4}}}",
            r_batch / r_row,
            r_mt / r_row
        );
    }
    table.print();

    let json = format!(
        "{{\n  \"bench\": \"predict\",\n  \"unit\": \"rows_per_sec\",\n  \
         \"results\": [\n{json_rows}\n  ]\n}}\n"
    );
    let out = "BENCH_predict.json";
    match std::fs::write(out, &json) {
        Ok(()) => println!("\n# wrote {out}"),
        Err(e) => eprintln!("\n# could not write {out}: {e}"),
    }
}
