//! Ablations over the design choices DESIGN.md calls out (not a paper
//! table; supports the §6 discussion):
//!
//!  A. bin count 64 vs 256 (8×8 AVX-2 layout vs 16×16 AVX-512 layout)
//!  B. projection sampler: naive Bernoulli vs Floyd (end-to-end, not micro)
//!  C. projection sparsity: nnz_factor sweep
//!  D. split criterion: entropy vs gini
//!
//! Each row reports end-to-end train time and holdout accuracy so both
//! sides of the trade-off are visible.

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::projection::SamplerKind;
use soforest::rng::Pcg64;
use soforest::split::{SplitCriterion, SplitStrategy};
use std::time::Instant;

fn main() {
    let n = std::env::var("SOFOREST_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    let data = TrunkConfig {
        n_samples: n,
        n_features: 128,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(19));
    let train_idx: Vec<u32> = (0..(n as u32) * 3 / 4).collect();
    let test_idx: Vec<u32> = ((n as u32) * 3 / 4..n as u32).collect();
    let train = data.subset(&train_idx);
    let test = data.subset(&test_idx);

    let base = ForestConfig {
        n_trees: 10,
        n_threads: 1,
        strategy: SplitStrategy::DynamicVectorized,
        ..Default::default()
    };
    let run = |cfg: &ForestConfig| -> (f64, f64) {
        let t0 = Instant::now();
        let f = train_forest(&train, cfg, 77);
        (t0.elapsed().as_secs_f64(), f.accuracy(&test))
    };

    println!("# Ablations (trunk {n}x128, 10 trees, dynamic-vectorized)\n");
    let mut table = Table::new(&["ablation", "variant", "train_s", "test_acc"]);

    // A: bin count.
    for bins in [64usize, 256] {
        let cfg = ForestConfig {
            n_bins: bins,
            ..base.clone()
        };
        let (t, a) = run(&cfg);
        table.row(&[
            "bins".into(),
            bins.to_string(),
            format!("{t:.2}"),
            format!("{a:.4}"),
        ]);
    }
    // B: sampler.
    for (name, sampler) in [("naive", SamplerKind::Naive), ("floyd", SamplerKind::Floyd)] {
        let cfg = ForestConfig {
            sampler,
            ..base.clone()
        };
        let (t, a) = run(&cfg);
        table.row(&[
            "sampler".into(),
            name.into(),
            format!("{t:.2}"),
            format!("{a:.4}"),
        ]);
    }
    // C: projection sparsity.
    for nnz in [1.5f64, 3.0, 6.0, 12.0] {
        let mut cfg = base.clone();
        cfg.projection.nnz_factor = nnz;
        let (t, a) = run(&cfg);
        table.row(&[
            "nnz_factor".into(),
            format!("{nnz}"),
            format!("{t:.2}"),
            format!("{a:.4}"),
        ]);
    }
    // D: criterion.
    for (name, criterion) in [
        ("entropy", SplitCriterion::Entropy),
        ("gini", SplitCriterion::Gini),
    ] {
        let cfg = ForestConfig {
            criterion,
            ..base.clone()
        };
        let (t, a) = run(&cfg);
        table.row(&[
            "criterion".into(),
            name.into(),
            format!("{t:.2}"),
            format!("{a:.4}"),
        ]);
    }
    table.print();
    println!("\n# expectations: 64-bin ~ faster but equal accuracy at this depth;");
    println!("# floyd ≈ naive accuracy with lower time on wide data; accuracy robust to nnz_factor;");
    println!("# gini ≈ entropy accuracy, slightly cheaper eval.");
}
