//! Table 4: holdout accuracy of Exact vs Histogram vs Dynamic vs Dynamic
//! Vectorized across the paper's datasets (synthetic analogs).
//!
//! Paper values (240 trees): Higgs 75.7 / SUSY 80.1 / Epsilon ~74.5 /
//! Bank 90.6 / Phishing 97.2-97.4 / Credit 86.3-86.5 / Ads 97.6-97.7 /
//! Trunk 96.4 — identical to ±0.2pp across methods. The reproduction
//! target is that *relative* property: all four methods statistically
//! indistinguishable per dataset.

use soforest::bench::Table;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest;
use soforest::data::synth;
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;

fn main() {
    let trees = std::env::var("SOFOREST_BENCH_TREES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(40usize);
    let scale: f64 = std::env::var("SOFOREST_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let sz = |b: usize| ((b as f64 * scale) as usize).max(400);
    println!("# Table 4: accuracy by training method, {trees} trees, 75/25 split\n");

    let datasets = [
        ("higgs", format!("higgs:{}", sz(20_000)), 0.757),
        ("susy", format!("susy:{}", sz(20_000)), 0.801),
        ("epsilon", format!("epsilon:{}", sz(4_000)), 0.746),
        ("bank-marketing", format!("bank-marketing:{}", sz(8_000)), 0.906),
        ("phishing", format!("phishing:{}", sz(6_000)), 0.974),
        ("credit-approval", "credit-approval:690".to_string(), 0.865),
        ("internet-ads", format!("internet-ads:{}", sz(2_000)), 0.977),
        ("trunk", format!("trunk:{}:256", sz(10_000)), 0.964),
    ];
    let strategies = [
        SplitStrategy::Exact,
        SplitStrategy::Histogram,
        SplitStrategy::Dynamic,
        SplitStrategy::DynamicVectorized,
    ];

    let mut table = Table::new(&[
        "dataset", "paper", "exact", "hist", "dynamic", "dyn_vec", "spread",
    ]);
    for (name, spec, paper) in &datasets {
        let mut rng = Pcg64::new(17);
        let data = synth::generate(spec, &mut rng).unwrap();
        let mut idx: Vec<u32> = (0..data.n_samples() as u32).collect();
        rng.shuffle(&mut idx);
        let n_test = data.n_samples() / 4;
        let test = data.subset(&idx[..n_test]);
        let train = data.subset(&idx[n_test..]);
        let mut accs = Vec::new();
        for &strategy in &strategies {
            let cfg = ForestConfig {
                n_trees: trees,
                n_threads: 1,
                strategy,
                ..Default::default()
            };
            let f = train_forest(&train, &cfg, 42);
            accs.push(f.accuracy(&test));
        }
        let max = accs.iter().cloned().fold(f64::MIN, f64::max);
        let min = accs.iter().cloned().fold(f64::MAX, f64::min);
        table.row(&[
            name.to_string(),
            format!("{:.1}%", paper * 100.0),
            format!("{:.1}%", accs[0] * 100.0),
            format!("{:.1}%", accs[1] * 100.0),
            format!("{:.1}%", accs[2] * 100.0),
            format!("{:.1}%", accs[3] * 100.0),
            format!("{:.1}pp", (max - min) * 100.0),
        ]);
        eprintln!("[{name}] done");
    }
    table.print();
    println!("\n# reproduction target: spread <= ~1pp per dataset (methods indistinguishable, paper Table 4)");
}
