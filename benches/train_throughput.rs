//! Training throughput: depth vs frontier growth at 1 and N threads,
//! frontier with sibling-histogram subtraction on vs off, the
//! storage backend sweep — in-memory float, memory-mapped float, and
//! quantized (`storage=binned`, 255-bin u8 columns with the direct
//! bin-id histogram fast path) — and the shard-count sweep
//! (`shards=1|2|4`): the same table split into contiguous row-range
//! members and trained fill-local/merge-global, so the per-shard
//! partial-fill + `merge_shard_tables` overhead is tracked as its own
//! gated rows (the forests are byte-identical by construction, see
//! tests/shard_equivalence.rs, so any delta is pure merge cost).
//!
//! The frontier scheduler's reason to exist is intra-tree parallelism: a
//! **single large tree** should scale with cores, where the depth-first
//! stack is pinned to one. Sibling-histogram subtraction rides on the same
//! scheduler: the larger half of each eligible sibling pair gets its count
//! tables by subtraction instead of an `O(n · p)` fill, so `frontier +
//! subtraction` rows should beat `frontier + no-subtraction` rows on the
//! wide histogram levels. The `storage=mmap` rows train the same
//! workload off a packed `.sofc` column file (written to a temp dir, page
//! cache warm after the first pass), so the chunk-view read path is
//! gate-checked against the in-memory backend: with the table fully
//! cached the two should be within noise of each other — a widening gap
//! means the mapped chunk path grew overhead. This bench trains one tree
//! to purity on a ≥100k-row synthetic table and emits `BENCH_train.json`
//! so the scaling trajectory is machine-readable across PRs (alongside
//! `BENCH_node_split.json` and `BENCH_predict.json`) and gate-checked by
//! `ci/bench_gate.py` against `BENCH_baseline/`.
//!
//! Env overrides: `SOFOREST_BENCH_TRAIN_ROWS` (default 100000),
//! `SOFOREST_BENCH_TRAIN_FEATURES` (default 64),
//! `SOFOREST_BENCH_TRAIN_THREADS` (default `1,<all>`).

use soforest::bench::Table;
use soforest::config::{ForestConfig, GrowthMode};
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::data::{colfile, shards, Dataset};
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use std::fmt::Write as _;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
}

fn main() {
    let rows = env_usize("SOFOREST_BENCH_TRAIN_ROWS", 100_000);
    let d = env_usize("SOFOREST_BENCH_TRAIN_FEATURES", 64);
    let all_threads = std::thread::available_parallelism().map_or(1, |n| n.get());
    let threads_sweep: Vec<usize> = std::env::var("SOFOREST_BENCH_TRAIN_THREADS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_else(|| {
            if all_threads > 1 {
                vec![1, all_threads]
            } else {
                vec![1]
            }
        });

    let data = TrunkConfig {
        n_samples: rows,
        n_features: d,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(0x7EA1));

    // Mapped twin of the same table: pack once, map read-only. Training
    // values are bit-identical (tests/storage_equivalence.rs), so the
    // mmap rows isolate pure storage-path overhead.
    // Pid-suffixed so concurrent bench runs on one machine never truncate
    // a file the other still has mapped.
    let sofc_path =
        std::env::temp_dir().join(format!("soforest_bench_train_{}.sofc", std::process::id()));
    let mapped: Option<Dataset> = match colfile::write_dataset(&data, &sofc_path)
        .and_then(|()| colfile::load_mapped(&sofc_path))
    {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("# skipping storage=mmap rows: {e}");
            None
        }
    };
    // Quantized twin (u8 bin ids, 255 bins): the storage=binned rows time
    // the whole quantized data path — 4x less column traffic plus the
    // direct bin-id accumulate for axis-aligned candidates. NOT
    // comparable accuracy-wise to the float rows (different forest); the
    // gate tracks its throughput trajectory, the eval e2e reports the
    // accuracy delta.
    let binned = data.quantized(255);
    // Sharded twins of the float table (contiguous row-range members, the
    // layout `gen-data --shards` writes): the shards=2|4 rows time the
    // fill-local/merge-global histogram tier against the shards=1 `ram`
    // row. Same forest bytes by construction, so the delta is the cost of
    // per-shard partial fills + the tree-structured count-table merge.
    let shard_k = |k: usize| -> Dataset {
        let parts: Vec<Dataset> = (0..k)
            .map(|i| {
                let ids: Vec<u32> = (i * rows / k..(i + 1) * rows / k)
                    .map(|r| r as u32)
                    .collect();
                data.subset(&ids)
            })
            .collect();
        shards::from_parts(parts).expect("contiguous row-range members")
    };
    let sharded2 = shard_k(2);
    let sharded4 = shard_k(4);

    println!("# single-tree training throughput, trunk:{rows}:{d}, to purity\n");
    // Speedup is relative to each (growth, subtraction, storage, shards)
    // group's FIRST sweep entry (1 thread in the default sweep); a custom
    // SOFOREST_BENCH_TRAIN_THREADS changes the baseline accordingly, so
    // the field is named "vs_first", not "vs_1t". Depth growth has no
    // sibling pairs, so only the subtraction=on default is timed there;
    // the mmap backend is swept at the frontier default config.
    let mut table = Table::new(&[
        "growth",
        "subtraction",
        "storage",
        "shards",
        "threads",
        "wall_s",
        "rows/s",
        "speedup_vs_first",
    ]);
    let mut json_rows = String::new();
    let mut first = true;
    let configs: Vec<(GrowthMode, bool, &str, usize, &Dataset)> = {
        let mut c: Vec<(GrowthMode, bool, &str, usize, &Dataset)> = vec![
            (GrowthMode::Depth, true, "ram", 1, &data),
            (GrowthMode::Frontier, true, "ram", 1, &data),
            (GrowthMode::Frontier, false, "ram", 1, &data),
        ];
        if let Some(m) = &mapped {
            c.push((GrowthMode::Frontier, true, "mmap", 1, m));
        }
        c.push((GrowthMode::Frontier, true, "binned", 1, &binned));
        c.push((GrowthMode::Frontier, true, "sharded", 2, &sharded2));
        c.push((GrowthMode::Frontier, true, "sharded", 4, &sharded4));
        c
    };
    for (growth, subtraction, storage, shards, bench_data) in configs {
        let mut base_wall = f64::NAN;
        for &threads in &threads_sweep {
            let cfg = ForestConfig {
                n_trees: 1,
                n_threads: threads,
                growth,
                hist_subtraction: subtraction,
                ..Default::default()
            };
            let out = train_forest_with_source(
                bench_data,
                &cfg,
                0x5EED,
                ProjectionSource::SparseOblique,
            );
            let rows_per_s = rows as f64 / out.wall_s;
            if threads == threads_sweep[0] {
                base_wall = out.wall_s;
            }
            let speedup = base_wall / out.wall_s;
            table.row(&[
                growth.name().to_string(),
                if subtraction { "on" } else { "off" }.to_string(),
                storage.to_string(),
                shards.to_string(),
                threads.to_string(),
                format!("{:.3}", out.wall_s),
                format!("{rows_per_s:.0}"),
                format!("{speedup:.2}x"),
            ]);
            if !first {
                json_rows.push_str(",\n");
            }
            first = false;
            let _ = write!(
                json_rows,
                "    {{\"growth\": \"{}\", \"hist_subtraction\": {subtraction}, \
                 \"storage\": \"{storage}\", \"shards\": {shards}, \"threads\": {threads}, \
                 \"rows\": {rows}, \"features\": {d}, \"wall_s\": {:.4}, \
                 \"rows_per_s\": {rows_per_s:.1}, \"speedup_vs_first\": {speedup:.3}}}",
                growth.name(),
                out.wall_s
            );
        }
    }
    table.print();
    std::fs::remove_file(&sofc_path).ok();

    let json = format!(
        "{{\n  \"bench\": \"train_throughput\",\n  \"unit\": \"rows_per_s\",\n  \
         \"n_trees\": 1,\n  \"results\": [\n{json_rows}\n  ]\n}}\n"
    );
    let out = "BENCH_train.json";
    match std::fs::write(out, &json) {
        Ok(()) => println!("\n# wrote {out}"),
        Err(e) => eprintln!("\n# could not write {out}: {e}"),
    }
}
