//! Table 3: end-to-end training, CPU-only vs hybrid CPU+accelerator,
//! including the Trunk scaling sweep.
//!
//! Paper (16-core + RTX PRO 6000, 128 trees): HIGGS 453.5→408.1 (+11.1%),
//! SUSY 150.7→140.9 (+7.0%), Epsilon 103.7→102.9 (+0.8%), Trunk-100k
//! 31.1→30.4 (+2.0%), Trunk-1M 348.4→319.5 (+9.0%), Trunk-10M
//! 1061.7→1754.7 — the paper's table shows GPU *hurting* at 10M? No:
//! improvement 39.5% (CPU 1754.7? numbers transposed in the paper's PDF);
//! the reproduced shape target is: benefit grows with dataset size and can
//! be ~0 for small/narrow data.

use soforest::accel::NodeSplitAccel;
use soforest::bench::Table;
use soforest::calibrate;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::histogram::Routing;
use soforest::split::SplitStrategy;
use std::path::Path;

fn main() {
    let artifacts = std::env::var("SOFOREST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let Ok(mut probe) = NodeSplitAccel::try_load(Path::new(&artifacts)) else {
        println!("# Table 3 skipped: no artifacts (run `make artifacts`)");
        return;
    };
    let scale: f64 = std::env::var("SOFOREST_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0);
    let trees = std::env::var("SOFOREST_BENCH_TREES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(4usize);
    let sz = |base: usize| ((base as f64 * scale) as usize).max(500);

    let sort_below = calibrate::calibrate_sort_threshold(256, Routing::TwoLevel).min(1 << 14);
    let accel_above = calibrate::calibrate_accel_threshold(&mut probe, 16, 256, 1 << 16);
    drop(probe);
    println!(
        "# Table 3: CPU vs hybrid, {trees} trees; calibrated offload above {}\n",
        if accel_above == usize::MAX { "never".into() } else { accel_above.to_string() }
    );

    // Trunk scaling sweep (paper: 100k / 1M / 10M) + dataset analogs.
    let datasets = [
        ("higgs", format!("higgs:{}", sz(60_000))),
        ("epsilon", format!("epsilon:{}", sz(8_000))),
        ("trunk-S", format!("trunk:{}:128", sz(10_000))),
        ("trunk-M", format!("trunk:{}:128", sz(40_000))),
        ("trunk-L", format!("trunk:{}:128", sz(120_000))),
    ];

    let mut table = Table::new(&[
        "dataset",
        "cpu_s",
        "hybrid_s",
        "improvement_%",
        "offloaded",
        "forced_s",
        "forced_off",
    ]);
    for (name, spec) in &datasets {
        let data = synth::generate(spec, &mut Pcg64::new(13)).unwrap();
        let mk = |strategy, accel_thr: usize| {
            let mut cfg = ForestConfig {
                n_trees: trees,
                n_threads: 1,
                strategy,
                artifacts_dir: artifacts.clone(),
                ..Default::default()
            };
            cfg.thresholds.sort_below = sort_below;
            cfg.thresholds.accel_above = accel_thr;
            cfg
        };
        let cpu = train_forest_with_source(
            &data,
            &mk(SplitStrategy::DynamicVectorized, usize::MAX),
            42,
            ProjectionSource::SparseOblique,
        );
        // Hybrid with the *calibrated* threshold (the paper's configuration).
        let hybrid = train_forest_with_source(
            &data,
            &mk(SplitStrategy::Hybrid, accel_above),
            42,
            ProjectionSource::SparseOblique,
        );
        // Forced offload of the top-of-tree nodes: quantifies what the PJRT
        // substrate costs when the dispatcher is overridden — on a real GPU
        // this row is where the paper's gains appear.
        let forced_thr = (data.n_samples() / 3).max(2048);
        let forced = train_forest_with_source(
            &data,
            &mk(SplitStrategy::Hybrid, forced_thr),
            42,
            ProjectionSource::SparseOblique,
        );
        table.row(&[
            name.to_string(),
            format!("{:.2}", cpu.wall_s),
            format!("{:.2}", hybrid.wall_s),
            format!("{:.1}", (cpu.wall_s - hybrid.wall_s) / cpu.wall_s * 100.0),
            hybrid.accel_nodes.to_string(),
            format!("{:.2}", forced.wall_s),
            forced.accel_nodes.to_string(),
        ]);
        eprintln!("[{name}] done");
    }
    table.print();
    println!("\n# paper shape: improvement grows with dataset size; ~0 for small/narrow data.");
    println!("# On this substrate the calibrated threshold is typically 'never' (a single CPU");
    println!("# core executing the XLA program cannot beat its own SIMD path), so hybrid == cpu");
    println!("# and improvement ~0; the forced columns show the dispatcher really offloads and");
    println!("# what that costs here (DESIGN.md §Hardware-Adaptation).");
}
