//! Figure 4: which split method the dynamic policy actually selects, as a
//! function of node cardinality, traced over a real training run.
//!
//! Paper shape: all nodes below the calibrated break-even sort; all above
//! histogram; both methods co-exist at the same tree depth.

use soforest::bench::Table;
use soforest::calibrate;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::metrics::METHOD_NAMES;
use soforest::rng::Pcg64;
use soforest::split::histogram::Routing;
use soforest::split::SplitStrategy;

fn main() {
    let n = std::env::var("SOFOREST_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30_000);
    let sort_below = calibrate::calibrate_sort_threshold(256, Routing::TwoLevel);
    let sort_below = if sort_below == usize::MAX { 1024 } else { sort_below };
    println!("# Fig 4: method selection by node cardinality (calibrated break-even {sort_below})\n");

    let data = TrunkConfig {
        n_samples: n,
        n_features: 128,
        ..Default::default()
    }
    .generate(&mut Pcg64::new(3));
    let mut cfg = ForestConfig {
        n_trees: 3,
        n_threads: 1,
        strategy: SplitStrategy::DynamicVectorized,
        instrument: true,
        ..Default::default()
    };
    cfg.thresholds.sort_below = sort_below;
    let out = train_forest_with_source(&data, &cfg, 5, ProjectionSource::SparseOblique);

    let mut table = Table::new(&["n_bucket", "exact", "histogram", "vectorized", "accelerator"]);
    for (bucket, counts) in out.stats.method_by_cardinality.iter().enumerate() {
        if counts.iter().all(|&c| c == 0) {
            continue;
        }
        let lo = 1usize << bucket.saturating_sub(1);
        let hi = (1usize << bucket) - 1;
        table.row(&[
            format!("{lo}-{hi}"),
            counts[0].to_string(),
            counts[1].to_string(),
            counts[2].to_string(),
            counts[3].to_string(),
        ]);
    }
    table.print();

    // Shape check: no vectorized-histogram node below break-even/2, no
    // exact node above 2x break-even.
    let mut violations = 0u64;
    for (bucket, counts) in out.stats.method_by_cardinality.iter().enumerate() {
        let hi = (1usize << bucket).saturating_sub(1);
        let lo = 1usize << bucket.saturating_sub(1);
        if hi < sort_below / 2 {
            violations += counts[2];
        }
        if lo > sort_below * 2 {
            violations += counts[0];
        }
    }
    println!("\n# selection violations outside break-even band: {violations} (expect 0)");
    let _ = METHOD_NAMES;
}
