#!/usr/bin/env python3
"""Bench-regression gate for the soforest CI.

Compares the three bench JSONs emitted by `cargo bench`
(BENCH_train.json, BENCH_node_split.json, BENCH_predict.json) against
the committed snapshots in BENCH_baseline/, prints a markdown delta
table to the job summary ($GITHUB_STEP_SUMMARY, falling back to
stdout), and exits non-zero when any matched row regresses by more
than TOLERANCE on its bench's throughput metric.

Baseline lifecycle:
  * a baseline file that is missing, has no rows, or carries
    `"provisional": true` is UNARMED — current numbers are recorded and
    the job FAILS with instructions, because an unarmed gate silently
    catches nothing (you cannot gate against numbers that were never
    measured on CI hardware, but you also must not merge thinking you
    are gated when you are not);
  * to arm (or refresh) the gate, download the `bench-baseline-candidate`
    artifact from a trusted run of this job and commit its files over
    BENCH_baseline/*.json with `"provisional": true` removed — the
    failure message names the artifact and the exact steps.

Rows are matched between baseline and current by per-bench key fields;
rows present on only one side are reported but never gated (bench
sweeps may grow or shrink across PRs).
"""

import json
import os
import sys

TOLERANCE = 0.15  # fail on >15% regression of the gated metric

# bench file -> (key fields, gated metrics, higher_is_better)
SPECS = {
    "BENCH_train.json": {
        # "storage" distinguishes the backends the trainer can read from
        # (rows keyed `ram` | `mmap` | `binned` | `sharded` — `binned` is
        # the quantized u8 bin-id store with the direct-accumulate fast
        # path, `sharded` the multi-member row-range store); "shards" (1
        # on single-store rows) keys the shard-count sweep so the
        # fill-local/merge-global overhead gates per shard count. Older
        # baselines without a row simply stop matching and are reported
        # as dropped/new rows until re-recorded.
        "keys": ("growth", "threads", "hist_subtraction", "storage", "shards"),
        "metrics": ("rows_per_s",),
        "higher_is_better": True,
    },
    "BENCH_node_split.json": {
        # "simd" ("on" | "off") tracks the runtime-dispatched kernels and
        # the forced-scalar reference path as separate sweep points, so a
        # regression in either shows up on its own row.
        "keys": ("n", "simd"),
        "metrics": ("fused_ns_per_sample",),
        "higher_is_better": False,
    },
    "BENCH_predict.json": {
        "keys": ("rows",),
        "metrics": ("batched_mt_rows_per_s",),
        "higher_is_better": True,
    },
    "BENCH_serve.json": {
        # Open-loop serve-load harness (benches/serve_load.rs): rows are
        # (connections, target arrival rate, metrics on|off) sweep points.
        # Two tails are gated per row: the harness-observed p99 measured
        # from the *scheduled* send time (coordinated-omission-safe) and
        # the server's own histogram p99 (server_p99_us, 0.0 on
        # metrics=off rows, which the zero-baseline guard passes through).
        # Older baselines without the "metrics" key field stop matching
        # and are reported as dropped/new rows until re-recorded.
        "keys": ("conns", "target_qps", "metrics"),
        "metrics": ("p99_us", "server_p99_us"),
        "higher_is_better": False,
    },
}

BASELINE_DIR = "BENCH_baseline"


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except json.JSONDecodeError as e:
        print(f"::error::{path} is not valid JSON: {e}")
        sys.exit(2)


def row_key(row, keys):
    # Absent key fields (older baseline schema) map to None so old rows
    # simply fail to match new ones instead of crashing the gate.
    return tuple(row.get(k) for k in keys)


def fmt_key(key, keys):
    return ", ".join(f"{k}={v}" for k, v in zip(keys, key))


def main():
    lines = ["# Bench-regression gate", ""]
    regressions = []
    unarmed = []
    for fname, spec in SPECS.items():
        current = load(fname)
        baseline = load(os.path.join(BASELINE_DIR, fname))
        lines.append(f"## {fname}")
        if current is None:
            print(f"::error::{fname} missing — did the bench step run?")
            regressions.append(f"{fname}: current results missing")
            lines.append("**current results missing** :x:\n")
            continue
        cur_rows = {row_key(r, spec["keys"]): r for r in current.get("results", [])}
        provisional = (
            baseline is None
            or baseline.get("provisional", False)
            or not baseline.get("results")
        )
        base_rows = (
            {}
            if baseline is None
            else {row_key(r, spec["keys"]): r for r in baseline.get("results", [])}
        )
        higher = spec["higher_is_better"]
        arrow = "higher is better" if higher else "lower is better"
        if provisional:
            unarmed.append(fname)
            lines.append(
                "_baseline provisional or empty — gate **UNARMED**, current numbers "
                "recorded below._ Commit this run's `bench-baseline-candidate` "
                f"artifact into `{BASELINE_DIR}/` (dropping `\"provisional\": true`) "
                "to arm the gate."
            )
        for metric in spec["metrics"]:
            lines.append("")
            lines.append(f"| {', '.join(spec['keys'])} | baseline {metric} | current {metric} | delta ({arrow}) | status |")
            lines.append("|---|---|---|---|---|")
            for key, cur in cur_rows.items():
                cur_v = cur.get(metric)
                base = base_rows.get(key)
                if cur_v is None:
                    # A metric this sweep point does not emit (e.g. an older
                    # bench binary) is reported, never gated.
                    lines.append(f"| {fmt_key(key, spec['keys'])} | — | missing `{metric}` | — | :warning: |")
                    continue
                if base is None or base.get(metric) is None:
                    lines.append(f"| {fmt_key(key, spec['keys'])} | — | {cur_v:.1f} | new row | recorded |")
                    continue
                base_v = base[metric]
                delta = (cur_v - base_v) / base_v if base_v else 0.0
                regressed = (delta < -TOLERANCE) if higher else (delta > TOLERANCE)
                status = ":x: REGRESSION" if regressed else ":white_check_mark:"
                lines.append(
                    f"| {fmt_key(key, spec['keys'])} | {base_v:.1f} | {cur_v:.1f} | {delta:+.1%} | {status} |"
                )
                if regressed and not provisional:
                    regressions.append(
                        f"{fname} [{fmt_key(key, spec['keys'])}]: {metric} {base_v:.1f} -> {cur_v:.1f} ({delta:+.1%})"
                    )
            for key in base_rows:
                if key not in cur_rows:
                    lines.append(f"| {fmt_key(key, spec['keys'])} | (baseline only) | dropped | — | :warning: |")
        lines.append("")

    if regressions:
        lines.append(f"**FAILED** — {len(regressions)} regression(s) beyond {TOLERANCE:.0%}:")
        lines.extend(f"- {r}" for r in regressions)
    elif unarmed:
        lines.append(
            f"**FAILED** — {len(unarmed)} baseline(s) provisional or empty; "
            "the gate is not actually protecting anything. To arm it:"
        )
        lines.append("1. open this run's `bench-baseline-candidate` artifact;")
        lines.append(
            f"2. copy its JSONs over `{BASELINE_DIR}/` "
            '(delete the `"provisional": true` field);'
        )
        lines.append("3. commit — the next run gates against those numbers.")
    else:
        lines.append(f"**PASSED** — no gated metric regressed beyond {TOLERANCE:.0%}.")

    report = "\n".join(lines) + "\n"
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(report)
    print(report)
    if regressions:
        for r in regressions:
            print(f"::error::bench regression: {r}")
        sys.exit(1)
    if unarmed:
        for fname in unarmed:
            print(
                f"::error::bench gate unarmed: {BASELINE_DIR}/{fname} is provisional or "
                "empty. Download the bench-baseline-candidate artifact from this run, "
                f"commit its {fname} into {BASELINE_DIR}/ with the "
                '"provisional": true field removed, and re-run.'
            )
        sys.exit(1)


if __name__ == "__main__":
    main()
