"""HLO cost analysis for the L2 graph (the §Perf L2 profiling tool).

Prints per-bucket op counts, estimated flops/bytes from XLA's own cost
model, and the VMEM footprint estimate for the L1 kernel's tiles — the
numbers DESIGN.md's TPU-performance discussion is based on.

Usage: python -m compile.analyze [--p 16] [--n 16384] [--impl pallas]
"""

import argparse
import collections
import sys

import jax

from .model import node_split, node_split_spec


def op_histogram(hlo_text: str) -> dict:
    counts = collections.Counter()
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line or line.startswith(("HloModule", "ENTRY", "}", "//")):
            continue
        rhs = line.split("=", 1)[1].strip()
        # "f32[16,4096]{1,0} broadcast(...)" -> op name after shape
        parts = rhs.split(" ")
        if len(parts) >= 2:
            op = parts[1].split("(")[0]
            counts[op] += 1
    return dict(counts)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--b", type=int, default=256)
    ap.add_argument("--impl", choices=["pallas", "cpu"], default="pallas")
    args = ap.parse_args()

    spec = node_split_spec(args.p, args.n, args.b)
    fn = lambda v, l, m, bd: node_split(v, l, m, bd, impl=args.impl)
    lowered = jax.jit(fn).lower(*spec)
    compiled = lowered.compile()

    print(f"# L2 cost analysis: p={args.p} n={args.n} b={args.b} impl={args.impl}\n")
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        for key in ["flops", "bytes accessed", "transcendentals", "optimal_seconds"]:
            if key in ca:
                print(f"{key:>18}: {ca[key]:.3e}")
    except Exception as e:  # noqa: BLE001 - cost analysis is best-effort
        print(f"cost_analysis unavailable: {e}", file=sys.stderr)

    hlo = lowered.compiler_ir("hlo").as_hlo_text()
    print("\n# top ops in the unoptimized HLO:")
    hist = op_histogram(hlo)
    for op, c in sorted(hist.items(), key=lambda kv: -kv[1])[:12]:
        print(f"{op:>24}: {c}")

    # L1 VMEM footprint estimate (DESIGN.md: interpret mode gives no TPU
    # timings; structure is what we can verify).
    block_n = min(4096, args.n)
    tiles = {
        "values block": block_n * 4,
        "boundary tile": args.b * 4,
        "compare tile [block,B] i32": block_n * args.b * 4,
        "hist accumulators (2x[B])": 2 * args.b * 4,
    }
    total = sum(tiles.values())
    print("\n# L1 kernel VMEM footprint per grid step:")
    for k, v in tiles.items():
        print(f"{k:>28}: {v/1e6:.2f} MB")
    print(f"{'total':>28}: {total/1e6:.2f} MB (TPU core VMEM ~16 MB)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
