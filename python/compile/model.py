"""L2: the node-split computation the rust coordinator offloads (§4.3).

Mirrors the paper's two GPU kernels:

  * kernel 1 — per-projection class histograms → the L1 Pallas kernel
    (`kernels.histogram.class_histogram`);
  * kernel 2 — best split per histogram (cumulative class counts, entropy
    gain at every edge, masked argmax) → plain jnp here, fused by XLA.

The whole graph is lowered once by `aot.py` into a single HLO module per
(P, N) shape bucket; the rust runtime compiles each bucket once and invokes
it per offloaded node. Conventions match rust/src/split/ exactly — see
kernels/ref.py for the contract and the tests for the cross-checks.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.histogram import class_histogram, class_histogram_cpu


def node_split(values, labels, mask, boundaries, impl="pallas"):
    """Best split per projection for one tree node.

    values: [P, N] f32, labels: [N] f32 {0,1}, mask: [N] f32 {0,1},
    boundaries: [P, B] f32 (sorted, +inf padded).

    `impl` selects the histogram-fill kernel: ``"pallas"`` (the L1 kernel,
    TPU-shaped, the default artifact) or ``"cpu"`` (searchsorted + scatter,
    faster on the CPU PJRT substrate — see kernels/histogram.py). Both are
    bit-identical.

    Returns (gains [P] f32, edges [P] i32). Invalid/padded projections get
    gain = ref.NEG. The caller (rust/src/accel) takes the argmax over real
    projections and maps the edge back to a threshold.
    """
    fill = class_histogram if impl == "pallas" else class_histogram_cpu
    hist0, hist1 = fill(values, labels, mask, boundaries)

    def per_proj(h0, h1):
        gains = ref.split_gains_ref(h0, h1)
        edge = jnp.argmax(gains).astype(jnp.int32)
        return gains[edge], edge

    gains, edges = jax.vmap(per_proj)(hist0, hist1)
    return gains, edges


def node_split_spec(p, n, b=256):
    """ShapeDtypeStructs for lowering a (P, N, B) bucket."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((p, n), f32),  # values
        jax.ShapeDtypeStruct((n,), f32),  # labels
        jax.ShapeDtypeStruct((n,), f32),  # mask
        jax.ShapeDtypeStruct((p, b), f32),  # boundaries
    )


def node_split_full(weights, columns, labels, mask, boundaries, impl="pallas"):
    """Full-node offload: projection apply **and** histogram split on the
    accelerator — both kernels of the paper's GPU implementation (§4.3).

    weights: [P, K] f32 densified projection matrix, columns: [K, N] f32
    gathered member columns, rest as in `node_split`.

    Returns (gains [P] f32, edges [P] i32).
    """
    from .kernels.projection import apply_projections, apply_projections_ref

    proj = apply_projections if impl == "pallas" else apply_projections_ref
    values = proj(weights, columns)
    return node_split(values, labels, mask, boundaries, impl=impl)


def node_split_full_spec(p, k, n, b=256):
    """ShapeDtypeStructs for lowering a full-node (P, K, N, B) bucket."""
    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((p, k), f32),  # weights
        jax.ShapeDtypeStruct((k, n), f32),  # columns
        jax.ShapeDtypeStruct((n,), f32),  # labels
        jax.ShapeDtypeStruct((n,), f32),  # mask
        jax.ShapeDtypeStruct((p, b), f32),  # boundaries
    )
