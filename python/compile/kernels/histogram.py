"""L1 Pallas kernel: vectorized class-histogram fill.

This is the paper's §4.2 hot-spot rethought for the TPU vector unit
(DESIGN.md §Hardware-Adaptation). On AVX-512 the paper routes one sample
with two 16-lane compares against a two-level boundary structure. On a TPU
the VPU operates on (8, 128) lane tiles, so the natural formulation is a
**single broadcast compare of a block of samples against *all* B boundary
lanes at once** — the two-level skip list collapses into one masked
reduction, and bin assignment plus one-hot accumulation fuse into the same
VMEM-resident loop:

  * grid = (P, N / BLOCK_N): one program per (projection, sample block);
  * the projection's B boundaries live in VMEM for the whole row of blocks;
  * ``bins = Σ_b (boundary_b <= v)``  — the branch-free count the rust
    side's ``route_16x16`` computes 16 lanes at a time;
  * one-hot accumulation ``hist += onehotᵀ · w`` targets the MXU
    (a [BLOCK_N, B]ᶠ³² matmul with a [BLOCK_N] weight vector).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; lowering through the interpreter emits plain HLO that both
the python tests and the rust runtime execute bit-identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sample-axis block. 4096 f32 lanes × (B=256) compare tile ≈ 4 MiB in VMEM —
# comfortably inside a TPU core's ~16 MiB VMEM next to the boundary tile
# and the [B, 2] accumulator.
BLOCK_N = 4096


def _make_hist_kernel(accumulate):
    """Kernel factory. `accumulate` picks the bin-count reduction:

    * ``"matmul"`` — one-hot [BLOCK_N, B] matmul, the MXU-shaped reduction
      a real TPU wants;
    * ``"scatter"`` — `zeros(B).at[bins].add(w)`, ~2× faster under the
      interpret-mode/CPU-PJRT execution this repo ships (scatter is serial
      on a real TPU — flip to "matmul" when compiling for hardware).

    Both are bit-identical (integer counts in f32) and covered by tests.
    """

    def kernel(values_ref, labels_ref, mask_ref, bounds_ref, hist0_ref, hist1_ref):
        v = values_ref[0, :]  # [BLOCK_N]
        b = bounds_ref[0, :]  # [B]
        nb = b.shape[-1]
        # Branch-free routing: count boundaries <= v (the §4.2 vectorized
        # compare, all B boundary lanes at once).
        cmp = (b[None, :] <= v[:, None]).astype(jnp.int32)  # [BLOCK_N, B]
        bins = jnp.clip(cmp.sum(axis=1), 0, nb - 1)
        labels = labels_ref[...]
        mask = mask_ref[...]
        w1 = mask * labels
        w0 = mask * (1.0 - labels)
        if accumulate == "matmul":
            onehot = (
                bins[:, None] == jax.lax.iota(jnp.int32, nb)[None, :]
            ).astype(jnp.float32)  # [BLOCK_N, B]
            part0 = w0 @ onehot  # [B]
            part1 = w1 @ onehot
        else:
            part0 = jnp.zeros(nb, jnp.float32).at[bins].add(w0)
            part1 = jnp.zeros(nb, jnp.float32).at[bins].add(w1)

        # First block of each projection initializes; later blocks accumulate.
        @pl.when(pl.program_id(1) == 0)
        def _init():
            hist0_ref[0, :] = part0
            hist1_ref[0, :] = part1

        @pl.when(pl.program_id(1) != 0)
        def _acc():
            hist0_ref[0, :] += part0
            hist1_ref[0, :] += part1

    return kernel


@functools.partial(jax.jit, static_argnames=("block_n", "accumulate"))
def class_histogram(values, labels, mask, boundaries, block_n=BLOCK_N, accumulate="scatter"):
    """Per-class histograms for every projection of a node.

    values: [P, N] f32 — projected features (rows padded with 0 beyond the
        real sample count; the mask zeroes their contribution).
    labels: [N] f32 in {0, 1}.
    mask:   [N] f32 in {0, 1} — 1 for real samples.
    boundaries: [P, B] f32 — sorted, +inf padded (B = 256).

    Returns (hist0, hist1): [P, B] f32 class-count histograms.
    """
    p, n = values.shape
    _, b = boundaries.shape
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} must be a multiple of block_n={block_n}"
    grid = (p, n // block_n)
    out_shape = [
        jax.ShapeDtypeStruct((p, b), jnp.float32),
        jax.ShapeDtypeStruct((p, b), jnp.float32),
    ]
    hist0, hist1 = pl.pallas_call(
        _make_hist_kernel(accumulate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),  # values
            pl.BlockSpec((block_n,), lambda i, j: (j,)),  # labels
            pl.BlockSpec((block_n,), lambda i, j: (j,)),  # mask
            pl.BlockSpec((1, b), lambda i, j: (i, 0)),  # boundaries
        ],
        out_specs=[
            pl.BlockSpec((1, b), lambda i, j: (i, 0)),
            pl.BlockSpec((1, b), lambda i, j: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(values, labels, mask, boundaries)
    return hist0, hist1


def class_histogram_cpu(values, labels, mask, boundaries):
    """CPU-PJRT-optimized formulation: `searchsorted` routing (O(N log B))
    plus scatter-add accumulation — no [N, B] intermediate at all.

    This is NOT the TPU kernel (no broadcast compare, no MXU reduction);
    it exists because the shipped artifacts execute on the CPU PJRT client,
    where the O(N·B) compare tile that a TPU eats for free dominates
    wall-clock. `aot.py --impl cpu` lowers this variant; bit-identical to
    the Pallas kernel (tests cross-check all three against ref.py).
    """
    b = boundaries.shape[-1]

    def per_projection(v, bd):
        bins = jnp.clip(jnp.searchsorted(bd, v, side="right"), 0, b - 1)
        h1 = jnp.zeros(b, jnp.float32).at[bins].add(mask * labels)
        h0 = jnp.zeros(b, jnp.float32).at[bins].add(mask * (1.0 - labels))
        return h0, h1

    return jax.vmap(per_projection)(values, boundaries)
