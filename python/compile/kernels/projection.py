"""L1 Pallas kernel: batched sparse-projection application.

Step (1) of the paper's per-node workflow (Fig 2) and the first kernel of
its GPU implementation (§4.3: "apply projections: sum the columns and
write the new sparse oblique features"). The coordinator densifies the
node's sparse projection matrix into a [P, K] weight tile over the K
*member* columns it gathered (K ≈ 3√d non-zeros across P projections, so
K stays small), and the kernel computes

    values[P, N] = weights[P, K] @ columns[K, N]

— a dense matmul, i.e. exactly the MXU-shaped reformulation of the
paper's per-thread column sums (DESIGN.md §Hardware-Adaptation: what CUDA
does with a (P, N) thread grid, a TPU does as a systolic matmul). Tiled
along N so the column block and the weight tile live in VMEM together.

interpret=True as everywhere: the CPU PJRT plugin cannot run Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 4096


def _proj_kernel(weights_ref, columns_ref, out_ref):
    """One sample-block grid step: out[P, block] = W[P, K] @ C[K, block]."""
    w = weights_ref[...]  # [P, K]
    c = columns_ref[...]  # [K, BLOCK_N]
    out_ref[...] = jnp.dot(w, c, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def apply_projections(weights, columns, block_n=BLOCK_N):
    """values[P, N] = weights[P, K] @ columns[K, N] (Pallas, tiled over N).

    weights: [P, K] f32 — densified sparse projection matrix (zeros for
        features a projection does not use).
    columns: [K, N] f32 — the gathered member columns for the node's
        active samples (padded columns are all-zero).
    """
    p, k = weights.shape
    k2, n = columns.shape
    assert k == k2, f"weights K={k} != columns K={k2}"
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} must divide block_n={block_n}"
    return pl.pallas_call(
        _proj_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((p, k), lambda j: (0, 0)),  # weights resident
            pl.BlockSpec((k, block_n), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((p, block_n), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((p, n), jnp.float32),
        interpret=True,
    )(weights, columns)


def apply_projections_ref(weights, columns):
    """Oracle: plain jnp matmul."""
    return weights @ columns
