"""Pure-jnp oracle for the node-split computation.

This is the correctness anchor of the whole accelerated path: the Pallas
kernel (histogram.py) and the full L2 graph (model.py) are validated against
these functions by pytest/hypothesis, and the rust integration test compares
the compiled artifact's output against the rust CPU splitter on identical
inputs.

Conventions (identical to the rust side, rust/src/split/):
  * ``bin(v) = #{ boundaries b : b <= v }`` clamped to ``B - 1``;
  * boundaries are sorted, padded with +inf to ``B`` slots;
  * edge ``k`` means threshold ``boundaries[k]``; left ⟺ ``v < b[k]``
    ⟺ ``bin <= k``;
  * gain is Shannon-entropy information gain in nats;
  * an edge is valid iff both sides are non-empty (min_leaf = 1).
"""

import jax
import jax.numpy as jnp

NEG = -1e30  # sentinel for invalid edges (avoid -inf arithmetic in f32)


def route_ref(values, boundaries):
    """Bin index per sample: #{b <= v}, clamped to B-1.

    values: [N] f32, boundaries: [B] f32 (sorted, +inf padded).
    Returns [N] int32.
    """
    b = boundaries.shape[-1]
    cmp = (boundaries[None, :] <= values[:, None]).astype(jnp.int32)
    return jnp.clip(cmp.sum(axis=1), 0, b - 1)


def class_histogram_ref(values, labels, mask, boundaries):
    """Per-class histograms for one projection.

    values: [N], labels: [N] (0/1 f32), mask: [N] (0/1 f32),
    boundaries: [B]. Returns (hist0, hist1), each [B] f32.
    """
    b = boundaries.shape[-1]
    bins = route_ref(values, boundaries)
    onehot = (bins[:, None] == jnp.arange(b)[None, :]).astype(jnp.float32)
    w1 = mask * labels
    w0 = mask * (1.0 - labels)
    return w0 @ onehot, w1 @ onehot


def batched_class_histogram_ref(values, labels, mask, boundaries):
    """values: [P, N], boundaries: [P, B] -> (hist0, hist1) each [P, B]."""
    return jax.vmap(lambda v, b: class_histogram_ref(v, labels, mask, b))(
        values, boundaries
    )


def _xlogx(x):
    """x * ln(x) with 0 ln 0 = 0, safe for f32."""
    return jnp.where(x > 0.0, x * jnp.log(jnp.maximum(x, 1e-30)), 0.0)


def entropy2(c0, c1):
    """Entropy (nats) of a 2-class count pair; 0 for empty nodes."""
    n = c0 + c1
    n_safe = jnp.maximum(n, 1e-30)
    # H = ln n - (c0 ln c0 + c1 ln c1)/n
    h = jnp.log(n_safe) - (_xlogx(c0) + _xlogx(c1)) / n_safe
    return jnp.where(n > 0.0, h, 0.0)


def split_gains_ref(hist0, hist1):
    """Information gain at every edge of one projection's histograms.

    hist0/hist1: [B]. Returns gains [B] with invalid edges = NEG.
    Edge k: left = bins 0..k (cumulative), right = rest. Edge B-1 is the
    +inf pad and always invalid.
    """
    b = hist0.shape[-1]
    left0 = jnp.cumsum(hist0)
    left1 = jnp.cumsum(hist1)
    n0 = left0[-1]
    n1 = left1[-1]
    n = n0 + n1
    right0 = n0 - left0
    right1 = n1 - left1
    nl = left0 + left1
    nr = right0 + right1
    n_safe = jnp.maximum(n, 1e-30)
    h_parent = entropy2(n0, n1)
    gain = (
        h_parent
        - (nl / n_safe) * entropy2(left0, left1)
        - (nr / n_safe) * entropy2(right0, right1)
    )
    valid = (nl > 0.0) & (nr > 0.0) & (jnp.arange(b) < b - 1)
    return jnp.where(valid, gain, NEG)


def node_split_ref(values, labels, mask, boundaries):
    """Full node-split oracle.

    values: [P, N], labels: [N], mask: [N], boundaries: [P, B].
    Returns (gains [P] f32, edges [P] i32): the best edge per projection
    (gain = NEG when no valid edge exists).
    """
    hist0, hist1 = batched_class_histogram_ref(values, labels, mask, boundaries)

    def per_proj(h0, h1):
        gains = split_gains_ref(h0, h1)
        edge = jnp.argmax(gains).astype(jnp.int32)
        return gains[edge], edge

    return jax.vmap(per_proj)(hist0, hist1)
