"""AOT compilation: lower the L2 node-split graph to HLO text artifacts.

HLO *text* (not `.serialize()`d protos) is the interchange format: jax
>= 0.5 emits HloModuleProtos with 64-bit instruction ids that the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--buckets small|full]

Emits one `node_split_p{P}_n{N}.hlo.txt` per shape bucket plus
`model.hlo.txt` (the smallest bucket, kept as the canonical "model"
artifact for the Makefile dependency and the quickstart example).
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import node_split, node_split_spec

# (P, N) shape buckets. P covers the paper's projection counts
# (1.5·sqrt(d): d=28 -> 8, d=2000 -> 68, d=4096 -> 96); N covers the node
# sizes worth offloading (the paper's GPU crossover is ~29k samples).
FULL_BUCKETS = [
    (16, 4096),
    (16, 16384),
    (16, 65536),
    (64, 16384),
    (64, 65536),
    (128, 16384),
    (128, 65536),
]
# Small grid for CI / quick builds.
SMALL_BUCKETS = [(16, 4096), (16, 16384)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(p: int, n: int, b: int = 256, impl: str = "pallas") -> str:
    spec = node_split_spec(p, n, b)
    lowered = jax.jit(lambda v, l, m, bd: node_split(v, l, m, bd, impl=impl)).lower(*spec)
    return to_hlo_text(lowered)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="(compat) path for model.hlo.txt")
    ap.add_argument(
        "--buckets",
        choices=["small", "full"],
        default=os.environ.get("SOFOREST_BUCKETS", "full"),
    )
    ap.add_argument(
        "--impl",
        choices=["pallas", "cpu"],
        default=os.environ.get("SOFOREST_AOT_IMPL", "pallas"),
        help="histogram kernel: 'pallas' (L1 kernel, TPU-shaped) or "
        "'cpu' (searchsorted+scatter, faster on the CPU PJRT substrate)",
    )
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out:  # `--out path/model.hlo.txt` form used by the Makefile
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    buckets = FULL_BUCKETS if args.buckets == "full" else SMALL_BUCKETS
    first_text = None
    for p, n in buckets:
        text = lower_bucket(p, n, impl=args.impl)
        path = os.path.join(out_dir, f"node_split_p{p}_n{n}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        if first_text is None:
            first_text = text
        print(f"wrote {path} ({len(text) / 1e3:.1f} kB)", file=sys.stderr)

    model_path = os.path.join(out_dir, "model.hlo.txt")
    with open(model_path, "w") as f:
        f.write(first_text)
    print(f"wrote {model_path}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
