"""L1 correctness: the Pallas histogram kernel vs the pure-jnp oracle.

This is the core correctness signal of the accelerated path — everything
downstream (the L2 graph, the AOT artifact, the rust accel module) consumes
the kernel's output verbatim.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.histogram import class_histogram


def make_node(rng, p, n, b, n_real=None, scale=1.0, duplicate_bounds=False):
    """Random padded node inputs in the exact layout rust/src/accel sends."""
    values = (rng.normal(size=(p, n)) * scale).astype(np.float32)
    labels = (rng.random(n) < 0.5).astype(np.float32)
    mask = np.ones(n, np.float32)
    if n_real is not None:
        mask[n_real:] = 0.0
    raw = rng.normal(size=(p, b - 1)).astype(np.float32) * scale
    if duplicate_bounds:
        raw[:, : (b - 1) // 2] = raw[:, :1]  # heavy boundary ties
    bounds = np.sort(raw, axis=1)
    bounds = np.concatenate([bounds, np.full((p, 1), np.inf, np.float32)], axis=1)
    return (
        jnp.array(values),
        jnp.array(labels),
        jnp.array(mask),
        jnp.array(bounds),
    )


def numpy_histogram(values, labels, mask, bounds):
    """Independent numpy reference (searchsorted), no jax code shared."""
    p, n = values.shape
    b = bounds.shape[1]
    h0 = np.zeros((p, b), np.float32)
    h1 = np.zeros((p, b), np.float32)
    for pi in range(p):
        # bin = #{b <= v} = searchsorted(side='right')
        bins = np.searchsorted(bounds[pi], values[pi], side="right")
        bins = np.clip(bins, 0, b - 1)
        for i in range(n):
            if mask[i] > 0:
                if labels[i] > 0.5:
                    h1[pi, bins[i]] += 1
                else:
                    h0[pi, bins[i]] += 1
    return h0, h1


class TestKernelVsOracle:
    @pytest.mark.parametrize("p,n", [(1, 2048), (3, 4096), (8, 8192)])
    def test_matches_ref(self, p, n):
        rng = np.random.default_rng(p * 1000 + n)
        args = make_node(rng, p, n, 256)
        h0, h1 = class_histogram(*args)
        r0, r1 = ref.batched_class_histogram_ref(*args)
        np.testing.assert_allclose(h0, r0, rtol=0, atol=0)
        np.testing.assert_allclose(h1, r1, rtol=0, atol=0)

    def test_matches_independent_numpy(self):
        rng = np.random.default_rng(7)
        args = make_node(rng, 4, 2048, 256)
        h0, h1 = class_histogram(*args)
        w0, w1 = numpy_histogram(*[np.asarray(a) for a in args])
        np.testing.assert_array_equal(np.asarray(h0), w0)
        np.testing.assert_array_equal(np.asarray(h1), w1)

    def test_mask_excludes_padding(self):
        rng = np.random.default_rng(9)
        args = make_node(rng, 2, 4096, 256, n_real=1000)
        h0, h1 = class_histogram(*args)
        total = float(h0.sum() + h1.sum())
        assert total == 2 * 1000  # P projections × real samples

    def test_duplicate_boundaries(self):
        rng = np.random.default_rng(11)
        args = make_node(rng, 2, 2048, 256, duplicate_bounds=True)
        h0, h1 = class_histogram(*args)
        r0, r1 = ref.batched_class_histogram_ref(*args)
        np.testing.assert_array_equal(np.asarray(h0), np.asarray(r0))
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(r1))

    def test_all_inf_boundaries_bin_zero(self):
        # Padded projections: all-inf boundaries put every sample in bin 0.
        rng = np.random.default_rng(13)
        values, labels, mask, _ = make_node(rng, 1, 2048, 256)
        bounds = jnp.full((1, 256), jnp.inf, jnp.float32)
        h0, h1 = class_histogram(values, labels, mask, bounds)
        assert float(h0[0, 0] + h1[0, 0]) == 2048
        assert float(h0[0, 1:].sum() + h1[0, 1:].sum()) == 0

    def test_extreme_values_land_in_last_bin(self):
        rng = np.random.default_rng(15)
        values, labels, mask, bounds = make_node(rng, 1, 2048, 256)
        values = values.at[0, :].set(1e30)
        h0, h1 = class_histogram(values, labels, mask, bounds)
        assert float(h0[0, 255] + h1[0, 255]) == 2048


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=6),
    n_blocks=st.integers(min_value=1, max_value=4),
    b=st.sampled_from([64, 256]),
    scale=st.floats(min_value=0.01, max_value=100.0),
    seed=st.integers(min_value=0, max_value=2**31),
    real_frac=st.floats(min_value=0.01, max_value=1.0),
)
def test_kernel_property_sweep(p, n_blocks, b, scale, seed, real_frac):
    """Hypothesis sweep over shapes, dtypes ranges and padding fractions."""
    n = 512 * n_blocks
    rng = np.random.default_rng(seed)
    n_real = max(1, int(n * real_frac))
    args = make_node(rng, p, n, b, n_real=n_real, scale=scale)
    h0, h1 = class_histogram(*args, block_n=512)
    r0, r1 = ref.batched_class_histogram_ref(*args)
    np.testing.assert_array_equal(np.asarray(h0), np.asarray(r0))
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(r1))
    # Mass conservation: every real sample lands in exactly one bin.
    assert float(h0.sum() + h1.sum()) == p * n_real


class TestKernelVariants:
    """The three fill implementations (pallas-scatter, pallas-matmul,
    cpu searchsorted+scatter) must be bit-identical."""

    def test_all_variants_agree(self):
        from compile.kernels.histogram import class_histogram_cpu

        rng = np.random.default_rng(21)
        args = make_node(rng, 3, 4096, 256, n_real=3000)
        scatter = class_histogram(*args, accumulate="scatter")
        matmul = class_histogram(*args, accumulate="matmul")
        cpu = class_histogram_cpu(*args)
        for a, b in [(scatter, matmul), (scatter, cpu)]:
            np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
            np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))

    def test_cpu_impl_model_matches_pallas_model(self):
        from compile.model import node_split

        rng = np.random.default_rng(22)
        args = make_node(rng, 4, 2048, 256)
        g1, e1 = node_split(*args, impl="pallas")
        g2, e2 = node_split(*args, impl="cpu")
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
        np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


class TestProjectionKernel:
    """L1 projection kernel vs the matmul oracle."""

    @pytest.mark.parametrize("p,k,n", [(4, 8, 1024), (16, 48, 2048), (1, 1, 512)])
    def test_matches_oracle(self, p, k, n):
        from compile.kernels.projection import apply_projections, apply_projections_ref

        rng = np.random.default_rng(p * 100 + k)
        w = jnp.array(rng.normal(size=(p, k)).astype(np.float32))
        c = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
        got = apply_projections(w, c, block_n=512)
        want = apply_projections_ref(w, c)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-5)

    def test_sparse_weights_sum_columns(self):
        from compile.kernels.projection import apply_projections

        # w = [[1, -1, 0]]: value = col0 - col1, col2 ignored.
        w = jnp.array([[1.0, -1.0, 0.0]], jnp.float32)
        c = jnp.array(
            [[1.0] * 512, [0.5] * 512, [9.0] * 512], jnp.float32
        )
        out = apply_projections(w, c, block_n=512)
        np.testing.assert_allclose(np.asarray(out), np.full((1, 512), 0.5), rtol=1e-6)

    def test_full_node_split_matches_two_stage(self):
        from compile.model import node_split, node_split_full

        rng = np.random.default_rng(5)
        p, k, n = 4, 12, 2048
        w = jnp.array(rng.normal(size=(p, k)).astype(np.float32))
        c = jnp.array(rng.normal(size=(k, n)).astype(np.float32))
        labels = jnp.array((rng.random(n) < 0.5).astype(np.float32))
        mask = jnp.ones(n, jnp.float32)
        values = np.asarray(w @ c)
        raw = np.sort(rng.normal(size=(p, 255)).astype(np.float32) * 3, axis=1)
        bounds = jnp.array(
            np.concatenate([raw, np.full((p, 1), np.inf, np.float32)], axis=1)
        )
        g1, e1 = node_split_full(w, c, labels, mask, bounds)
        g2, e2 = node_split(jnp.array(values), labels, mask, bounds)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)
