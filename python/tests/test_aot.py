"""AOT pipeline: the lowered HLO text must be parseable, shape-correct and
numerically identical to eager execution.

The rust runtime's own integration test (rust/tests/accel_integration.rs)
re-checks the same artifact through PJRT; here we verify the python half.
"""

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.aot import lower_bucket
from compile.model import node_split

from .test_kernel import make_node


def test_lowered_hlo_text_structure():
    text = lower_bucket(4, 1024)
    assert text.startswith("HloModule")
    assert "f32[4,1024]" in text  # values param
    assert "f32[4,256]" in text  # boundaries param
    # Output tuple: gains f32[4], edges s32[4].
    assert "(f32[4]" in text and "s32[4]" in text


def test_hlo_text_roundtrips_through_xla_parser():
    """Parse the text back into an HLO module — the same entry point the
    xla crate's `HloModuleProto::from_text_file` uses. (End-to-end
    execution through PJRT is covered by rust/tests/accel_integration.rs.)"""
    p, n = 4, 1024
    text = lower_bucket(p, n)
    mod = xc._xla.hlo_module_from_text(text)
    assert mod is not None
    rendered = mod.to_string()
    # All four parameters and the (gains, edges) result survive the parse.
    assert "f32[4,1024]" in rendered
    assert "f32[4,256]" in rendered
    assert "f32[4]" in rendered and "s32[4]" in rendered


def test_eager_matches_jit_of_lowered_fn():
    """The jitted function (what gets lowered) agrees with eager."""
    rng = np.random.default_rng(0)
    args = make_node(rng, 4, 1024, 256)
    want_gains, want_edges = node_split(*args)
    got_gains, got_edges = jax.jit(node_split)(*args)
    np.testing.assert_allclose(
        np.asarray(got_gains), np.asarray(want_gains), atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(got_edges), np.asarray(want_edges))


def test_distinct_buckets_lower_distinct_shapes():
    a = lower_bucket(2, 512)
    b = lower_bucket(3, 512)
    assert "f32[2,512]" in a
    assert "f32[3,512]" in b
