"""L2 correctness: the node-split graph vs a brute-force splitter.

The brute-force check re-derives the best split with plain python loops
(sort nothing, just try every edge) so a bug shared between model.py and
ref.py cannot hide.
"""

import math

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.model import node_split

from .test_kernel import make_node


def brute_force_best_edge(values, labels, mask, bounds):
    """Try every edge of one projection with float64 math."""
    b = bounds.shape[0]
    real = mask > 0
    v = values[real]
    y = labels[real]
    n = len(v)
    n1 = float(y.sum())
    n0 = n - n1

    def entropy(c0, c1):
        tot = c0 + c1
        if tot <= 0:
            return 0.0
        h = 0.0
        for c in (c0, c1):
            if c > 0:
                p = c / tot
                h -= p * math.log(p)
        return h

    h_parent = entropy(n0, n1)
    best = (ref.NEG, 0)
    for k in range(b - 1):
        t = bounds[k]
        left = v < t
        nl = int(left.sum())
        nr = n - nl
        if nl == 0 or nr == 0:
            continue
        l1 = float(y[left].sum())
        l0 = nl - l1
        gain = (
            h_parent
            - nl / n * entropy(l0, l1)
            - nr / n * entropy(n1 - l1, n0 - l0)
        )
        if gain > best[0]:
            best = (gain, k)
    return best


class TestNodeSplit:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_matches_brute_force(self, seed):
        rng = np.random.default_rng(seed)
        p, n = 4, 2048
        args = make_node(rng, p, n, 256)
        gains, edges = node_split(*args)
        npv = [np.asarray(a) for a in args]
        for pi in range(p):
            want_gain, _ = brute_force_best_edge(
                npv[0][pi], npv[1], npv[2], npv[3][pi]
            )
            got_gain = float(gains[pi])
            got_edge = int(edges[pi])
            # f32 vs f64 entropy: compare gains, and verify the chosen
            # edge's true (f64) gain is within tolerance of the best.
            edge_gain, _ = brute_force_edge_gain(
                npv[0][pi], npv[1], npv[2], npv[3][pi], got_edge
            )
            assert got_gain == pytest.approx(edge_gain, abs=5e-4)
            assert edge_gain >= want_gain - 5e-4, (
                f"proj {pi}: picked edge {got_edge} gain {edge_gain}, "
                f"best {want_gain}"
            )

    def test_separable_projection_wins(self):
        # Projection 0 is noise; projection 1 perfectly separates.
        n, b = 2048, 256
        rng = np.random.default_rng(42)
        labels = (rng.random(n) < 0.5).astype(np.float32)
        noise = rng.normal(size=n).astype(np.float32)
        signal = np.where(labels > 0.5, 1.0, -1.0).astype(np.float32)
        values = np.stack([noise, signal])
        mask = np.ones(n, np.float32)
        raw = np.sort(rng.normal(size=(2, b - 1)).astype(np.float32), axis=1)
        bounds = np.concatenate(
            [raw, np.full((2, 1), np.inf, np.float32)], axis=1
        )
        gains, edges = node_split(
            jnp.array(values), jnp.array(labels), jnp.array(mask), jnp.array(bounds)
        )
        assert float(gains[1]) > float(gains[0])
        assert float(gains[1]) == pytest.approx(math.log(2), abs=2e-3)
        # Edge threshold must lie in (-1, 1].
        t = bounds[1, int(edges[1])]
        assert -1.0 < t <= 1.0

    def test_all_one_class_no_valid_gain(self):
        rng = np.random.default_rng(3)
        values, _, mask, bounds = make_node(rng, 2, 2048, 256)
        labels = jnp.zeros(2048, jnp.float32)
        gains, _ = node_split(values, labels, mask, bounds)
        assert float(jnp.max(gains)) <= 1e-6

    def test_padded_projection_never_wins(self):
        rng = np.random.default_rng(4)
        values, labels, mask, bounds = make_node(rng, 3, 2048, 256)
        # Projection 2 is padding: all-inf boundaries.
        bounds = bounds.at[2].set(jnp.inf)
        gains, _ = node_split(values, labels, mask, bounds)
        assert float(gains[2]) < -1e29  # NEG sentinel (f32-rounded)


def brute_force_edge_gain(values, labels, mask, bounds, k):
    """f64 gain of a specific edge (for comparing f32 argmax picks)."""
    real = mask > 0
    v = values[real]
    y = labels[real]
    n = len(v)
    n1 = float(y.sum())
    n0 = n - n1

    def entropy(c0, c1):
        tot = c0 + c1
        if tot <= 0:
            return 0.0
        h = 0.0
        for c in (c0, c1):
            if c > 0:
                p = c / tot
                h -= p * math.log(p)
        return h

    t = bounds[k]
    left = v < t
    nl = int(left.sum())
    nr = n - nl
    if nl == 0 or nr == 0:
        return (ref.NEG, k)
    l1 = float(y[left].sum())
    l0 = nl - l1
    gain = (
        entropy(n0, n1)
        - nl / n * entropy(l0, l1)
        - nr / n * entropy(n1 - l1, n0 - l0)
    )
    return (gain, k)


@settings(max_examples=15, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
    real_frac=st.floats(min_value=0.05, max_value=1.0),
    shift=st.floats(min_value=0.0, max_value=3.0),
)
def test_node_split_property(p, seed, real_frac, shift):
    """Gains are finite & bounded by ln 2; the edge's recomputed f64 gain
    matches; padding never contributes."""
    n = 2048
    rng = np.random.default_rng(seed)
    n_real = max(4, int(n * real_frac))
    values, labels, mask, bounds = [
        np.asarray(a) for a in make_node(rng, p, n, 256, n_real=n_real)
    ]
    # Inject class signal so positive gains exist.
    values = values + shift * np.where(labels > 0.5, 1.0, -1.0)[None, :]
    gains, edges = node_split(
        jnp.array(values.astype(np.float32)),
        jnp.array(labels),
        jnp.array(mask),
        jnp.array(bounds),
    )
    for pi in range(p):
        g = float(gains[pi])
        if g == ref.NEG:
            continue
        assert -1e-3 <= g <= math.log(2) + 1e-3
        want, _ = brute_force_edge_gain(
            values[pi], labels, mask, bounds[pi], int(edges[pi])
        )
        assert g == pytest.approx(want, abs=2e-3)
