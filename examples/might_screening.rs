//! End-to-end MIGHT screening workload (the paper's motivating application,
//! §2): honest sparse-oblique forests for a cancer-screening-style task
//! where false positives are expensive.
//!
//! The workload mirrors the Wise-1 shape class (wide data, few samples):
//! a synthetic "liquid biopsy" panel — 2000 features of which a small block
//! carries class signal — split into train/calibrate/validate per tree,
//! scored honestly, and summarized with the statistics MIGHT reports:
//! ROC-AUC, sensitivity at 98% specificity, and the coefficient of
//! variation of S@98 across replicates.
//!
//! Run: `cargo run --release --example might_screening [-- --fast]`
//! This run is recorded in EXPERIMENTS.md (E12).

use soforest::config::ForestConfig;
use soforest::data::synth::tabular;
use soforest::might::{metrics, train_might, MightConfig};
use soforest::rng::Pcg64;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let (n_samples, n_trees, replicates) = if fast { (400, 20, 2) } else { (1200, 60, 5) };

    // Epsilon-like panel: 2000 dense features, weak distributed signal —
    // the regime where oblique projections shine and axis-aligned RF lags.
    let mut rng = Pcg64::new(2026);
    let data = tabular::epsilon_like(&mut rng, n_samples);
    println!(
        "screening panel: {} samples x {} features ({:.1} MB)",
        data.n_samples(),
        data.n_features(),
        data.nbytes() as f64 / 1e6
    );

    let forest_cfg = ForestConfig {
        n_trees,
        min_leaf: 1, // train to purity — the MIGHT regime
        ..Default::default()
    };
    let might_cfg = MightConfig::default();

    let mut aucs = Vec::new();
    let mut s98s = Vec::new();
    for r in 0..replicates {
        let t0 = std::time::Instant::now();
        let mf = train_might(&data, &forest_cfg, &might_cfg, 1000 + r as u64);
        let pairs = mf.scored_pairs(&data);
        let auc = metrics::roc_auc(&pairs);
        let s98 = metrics::sensitivity_at_specificity(&pairs, 0.98);
        let covered = mf.coverage.iter().filter(|&&c| c > 0).count();
        println!(
            "replicate {r}: AUC {auc:.4}  S@98 {s98:.4}  ({covered}/{} scored, {:.1}s)",
            data.n_samples(),
            t0.elapsed().as_secs_f64()
        );
        aucs.push(auc);
        s98s.push(s98);
    }

    let cov_auc = metrics::coefficient_of_variation(&aucs);
    let cov_s98 = metrics::coefficient_of_variation(&s98s);
    println!("\nacross {replicates} replicates:");
    println!(
        "  AUC  mean {:.4}  CoV {:.4}",
        aucs.iter().sum::<f64>() / aucs.len() as f64,
        cov_auc
    );
    println!(
        "  S@98 mean {:.4}  CoV {:.4}",
        s98s.iter().sum::<f64>() / s98s.len() as f64,
        cov_s98
    );
    println!("\nLow CoV at fixed specificity is MIGHT's calibration guarantee —");
    println!("the property the paper's performance work makes affordable at scale.");
}
