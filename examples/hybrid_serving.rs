//! Train-to-serve, end to end, on the production serving stack:
//!
//! 1. train a sparse-oblique forest (hybrid CPU/accelerator when AOT
//!    artifacts exist, pure CPU otherwise — the example no longer *requires*
//!    an accelerator),
//! 2. save it in the v2 packed format (`forest::serialize`), whose on-disk
//!    layout is the serving layout,
//! 3. load it back as a [`PackedForest`] (no per-node rebuild) and stand up
//!    the batching TCP server (`serve::serve_tcp`),
//! 4. fire client traffic at it and report end-to-end latency percentiles.
//!
//! Run: `cargo run --release --example hybrid_serving [-- --fast]`

use soforest::accel::NodeSplitAccel;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::serialize;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::serve::{percentile, serve_tcp, ServeConfig, Shutdown};
use soforest::split::SplitStrategy;
use std::io::{BufRead, BufReader, Write};
use std::path::Path;
use std::time::{Duration, Instant};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let artifacts = std::env::var("SOFOREST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // 1. Train — hybrid when the accelerator artifacts are present.
    let strategy = match NodeSplitAccel::try_load(Path::new(&artifacts)) {
        Ok(a) => {
            println!("accelerator: PJRT {} — training hybrid", a.platform());
            SplitStrategy::Hybrid
        }
        Err(e) => {
            println!("accelerator unavailable ({e}) — training on CPU");
            SplitStrategy::DynamicVectorized
        }
    };
    let n = if fast { 4_000 } else { 20_000 };
    let mut rng = Pcg64::new(7);
    let data = TrunkConfig {
        n_samples: n,
        n_features: 32,
        ..Default::default()
    }
    .generate(&mut rng);
    let mut cfg = ForestConfig {
        n_trees: if fast { 8 } else { 48 },
        strategy,
        artifacts_dir: artifacts,
        ..Default::default()
    };
    if strategy == SplitStrategy::Hybrid {
        // The default accel_above is usize::MAX ("never offload"); cap it
        // so the top-of-tree nodes actually exercise the accelerator.
        cfg.thresholds.accel_above = (n / 2).max(1024);
    }
    let trained = train_forest_with_source(&data, &cfg, 11, ProjectionSource::SparseOblique);
    println!(
        "trained {} trees in {:.2}s (train acc {:.4})",
        trained.forest.n_trees(),
        trained.wall_s,
        trained.forest.accuracy(&data)
    );

    // 2. Save v2, 3. load packed.
    let model_path = std::env::temp_dir().join("soforest_example_model.bin");
    serialize::save(&trained.forest, &model_path).expect("save model");
    let packed = serialize::load_packed(&model_path).expect("load packed model");
    println!(
        "model: {:.1} kB packed, format v2 (layout == serving layout)",
        packed.nbytes() as f64 / 1e3
    );

    // 4. Serve over TCP and drive client load.
    let n_requests = if fast { 500 } else { 5_000 };
    let port_file = std::env::temp_dir().join("soforest_example_port");
    std::fs::remove_file(&port_file).ok();
    let serve_cfg = ServeConfig::new()
        .with_max_batch(64)
        .with_max_wait(Duration::from_micros(500))
        .with_port_file(&port_file);
    std::thread::scope(|scope| {
        let server = scope.spawn(|| {
            serve_tcp(
                &packed,
                &serve_cfg,
                // Exact request budget: the server drains and returns by
                // itself once the client's last request is answered.
                &Shutdown::with_budget(Some(n_requests)),
            )
            .expect("serve")
        });
        let addr = loop {
            match std::fs::read_to_string(&port_file) {
                Ok(s) if !s.is_empty() => break s,
                _ => std::thread::sleep(Duration::from_millis(2)),
            }
        };
        println!("serving on {addr}; sending {n_requests} requests...");
        let mut conn = std::net::TcpStream::connect(addr.trim()).expect("connect");
        let mut responses = BufReader::new(conn.try_clone().expect("clone"));
        let mut row = Vec::new();
        let mut latencies = Vec::with_capacity(n_requests);
        let mut line = String::new();
        let t0 = Instant::now();
        for i in 0..n_requests {
            data.row(i % data.n_samples(), &mut row);
            let req: Vec<String> = row.iter().map(|v| v.to_string()).collect();
            let t = Instant::now();
            writeln!(conn, "{}", req.join(",")).expect("send");
            conn.flush().expect("flush");
            line.clear();
            responses.read_line(&mut line).expect("recv");
            latencies.push(t.elapsed().as_secs_f64() * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        // Shut the socket down (a plain drop would leave the cloned read
        // half holding the connection open and the server waiting).
        conn.shutdown(std::net::Shutdown::Both).ok();
        let stats = server.join().expect("server thread");
        latencies.sort_by(f64::total_cmp);
        println!(
            "client: {n_requests} request/response round trips in {wall:.2}s \
             ({:.0} req/s) — us p50 {:.0} p95 {:.0} p99 {:.0}",
            n_requests as f64 / wall,
            percentile(&latencies, 50.0),
            percentile(&latencies, 95.0),
            percentile(&latencies, 99.0),
        );
        println!("server: {}", stats.summary());
        // The server measured itself on its lock-free histogram: in-server
        // time only, so its percentiles sit at or below the client's
        // round-trip numbers.
        println!(
            "server-side us ({} samples): p50 {:.0} p99 {:.0}",
            stats.latency.count,
            stats.latency.quantile(50.0),
            stats.latency.quantile(99.0),
        );
    });
    std::fs::remove_file(&model_path).ok();
    std::fs::remove_file(&port_file).ok();
}
