//! Hybrid CPU/accelerator training (§4.3) end to end: load the AOT
//! artifacts, calibrate the CPU↔accelerator crossover, train with per-node
//! offload and compare against the pure-CPU run — the full three-layer
//! stack (rust coordinator → PJRT runtime → XLA executable embedding the
//! Pallas histogram kernel) on one small real workload.
//!
//! Run: `make artifacts && cargo run --release --example hybrid_serving [-- --fast]`

use soforest::accel::NodeSplitAccel;
use soforest::calibrate;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::SplitStrategy;
use std::path::Path;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let artifacts = std::env::var("SOFOREST_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // 1. Probe the accelerator.
    let mut accel = match NodeSplitAccel::try_load(Path::new(&artifacts)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("no accelerator ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    println!("accelerator: PJRT {}", accel.platform());
    for b in accel.buckets() {
        println!("  compiled bucket: p={:<4} n={}", b.p, b.n);
    }

    // 2. Calibrate both crossovers (paper Fig 3).
    let sort_below = calibrate::calibrate_sort_threshold(256, soforest::split::histogram::Routing::TwoLevel);
    let accel_above = calibrate::calibrate_accel_threshold(&mut accel, 16, 256, 1 << 16);
    println!("\ncalibration: sort below {sort_below}, offload above {}", fmt(accel_above));

    // 3. Train hybrid vs CPU on a dataset big enough to cross the offload
    //    threshold at the top of the tree.
    let n = if fast { 6_000 } else { 40_000 };
    let mut rng = Pcg64::new(7);
    let data = TrunkConfig {
        n_samples: n,
        n_features: 64,
        ..Default::default()
    }
    .generate(&mut rng);
    println!("\ndataset: trunk {}x{}", data.n_samples(), data.n_features());

    let mk = |strategy| {
        let mut cfg = ForestConfig {
            n_trees: if fast { 4 } else { 16 },
            strategy,
            artifacts_dir: artifacts.clone(),
            ..Default::default()
        };
        cfg.thresholds.sort_below = sort_below.min(4096);
        // Use the calibrated offload point, but cap it so the example
        // always exercises the accelerator path on this dataset.
        cfg.thresholds.accel_above = accel_above.min(n / 2);
        cfg
    };

    let cpu = train_forest_with_source(
        &data,
        &mk(SplitStrategy::DynamicVectorized),
        11,
        ProjectionSource::SparseOblique,
    );
    println!(
        "\nCPU   (dynamic-vectorized): {:.2}s  train acc {:.4}",
        cpu.wall_s,
        cpu.forest.accuracy(&data)
    );
    let hybrid = train_forest_with_source(
        &data,
        &mk(SplitStrategy::Hybrid),
        11,
        ProjectionSource::SparseOblique,
    );
    println!(
        "HYBRID (cpu+accelerator)  : {:.2}s  train acc {:.4}  ({} nodes offloaded)",
        hybrid.wall_s,
        hybrid.forest.accuracy(&data),
        hybrid.accel_nodes
    );

    let delta = (cpu.wall_s - hybrid.wall_s) / cpu.wall_s * 100.0;
    println!(
        "\nhybrid vs cpu: {delta:+.1}% wall-clock — the offload pays only above the\n\
         calibrated node size, exactly the economics of the paper's Table 3."
    );
}

fn fmt(t: usize) -> String {
    if t == usize::MAX {
        "never (CPU wins at every size on this box)".into()
    } else {
        t.to_string()
    }
}
