//! Quickstart: train a sparse-oblique forest with vectorized adaptive
//! histograms on the Trunk benchmark, compare all split strategies, and
//! verify they agree — a 30-second tour of the library's public API.
//!
//! Run: `cargo run --release --example quickstart`

use soforest::calibrate;
use soforest::config::ForestConfig;
use soforest::coordinator::train_forest_with_source;
use soforest::data::synth::trunk::TrunkConfig;
use soforest::forest::tree::ProjectionSource;
use soforest::rng::Pcg64;
use soforest::split::histogram::Routing;
use soforest::split::SplitStrategy;

fn main() {
    // 1. Data: the paper's Trunk synthetic benchmark (2 Gaussian classes,
    //    signal decaying as 1/sqrt(feature index)).
    let mut rng = Pcg64::new(42);
    let data = TrunkConfig {
        n_samples: 4000,
        n_features: 64,
        ..Default::default()
    }
    .generate(&mut rng);
    let train_idx: Vec<u32> = (0..3000).collect();
    let test_idx: Vec<u32> = (3000..4000).collect();
    let train = data.subset(&train_idx);
    let test = data.subset(&test_idx);
    println!(
        "Trunk: {} train / {} test samples, {} features",
        train.n_samples(),
        test.n_samples(),
        train.n_features()
    );

    // 2. Calibrate the sort<->histogram crossover for this machine (§4.1).
    let sort_below = calibrate::calibrate_sort_threshold(256, Routing::TwoLevel);
    println!("calibrated crossover: sort below {sort_below} samples\n");

    // 3. Train with each strategy and compare (paper Tables 2 & 4 in
    //    miniature).
    println!(
        "{:<22} {:>9} {:>10} {:>7} {:>11}",
        "strategy", "train_s", "test_acc", "depth", "nodes"
    );
    for strategy in [
        SplitStrategy::Exact,
        SplitStrategy::Histogram,
        SplitStrategy::Dynamic,
        SplitStrategy::DynamicVectorized,
    ] {
        let mut cfg = ForestConfig {
            n_trees: 30,
            strategy,
            ..Default::default()
        };
        cfg.thresholds.sort_below = if sort_below == usize::MAX {
            1024
        } else {
            sort_below
        };
        let out = train_forest_with_source(
            &train,
            &cfg,
            42,
            ProjectionSource::SparseOblique,
        );
        println!(
            "{:<22} {:>9.2} {:>10.4} {:>7.1} {:>11}",
            strategy.name(),
            out.wall_s,
            out.forest.accuracy(&test),
            out.forest.mean_depth(),
            out.forest.n_nodes()
        );
    }

    println!("\nThe dynamic strategies track the fastest engine per node while");
    println!("matching exact-split accuracy — the paper's headline result.");
}
